"""End-to-end serving driver (the paper's kind of workload): train a small
model on the synthetic chained-arithmetic CoT task in-framework, then serve
batched reasoning requests through the continuous-batching scheduler under
the full policy grid, reporting accuracy and per-request serving metrics
(TTFT, queue wait) — Tables 1–3 in miniature.

Requests walk the queued -> prefilling -> decoding -> finished lifecycle;
finished slots are refilled from the queue between decode segments, and
generation is EOS-aware (pass ``eos_id`` to ``Scheduler``/``Engine.generate``
and rows stop as soon as they emit it).

    PYTHONPATH=src python examples/serve_reasoning.py [--steps 400]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import pipeline
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    model, params = common.train_model("reasoning", steps_n=args.steps)
    dcfg = common.REASONING
    rng = np.random.default_rng(0)

    print(f"\nServing {args.requests} reasoning requests on "
          f"{args.slots} continuously-batched slots:")
    for kind in common.POLICY_GRID:
        cap = dcfg.seq_len + 16 if kind == "fullkv" else 48
        pol = common.make_policy_for(kind, cap)
        eng = Engine(model, params, pol)
        sched = Scheduler(eng, batch_slots=args.slots, segment_len=4)
        answers, reqs = [], []
        for i in range(args.requests):
            b = pipeline.reasoning_batch(
                pipeline.ReasoningConfig(
                    n_values=dcfg.n_values, n_steps=dcfg.n_steps,
                    batch_size=1, seed=50_000 + i), 0)
            ap_pos = int(b["answer_pos"])
            reqs.append(Request(uid=i,
                                prompt=np.asarray(b["tokens"][0, :ap_pos]),
                                max_new_tokens=1))
            answers.append(int(b["answer"][0]))
        sched.submit(reqs)
        done = sched.run()
        correct = sum(int(c.tokens[0]) == a for c, a in zip(done, answers))
        ttft = 1e3 * np.mean([c.ttft_s for c in done])
        wait = 1e3 * np.mean([c.queue_wait_s for c in done])
        print(f"  {kind:10s} capacity={cap:4d}  answer accuracy "
              f"{correct}/{args.requests}  mean TTFT {ttft:6.1f} ms "
              f"(queue wait {wait:6.1f} ms)")


if __name__ == "__main__":
    main()
