"""Train a small model end-to-end with the full substrate (data pipeline,
AdamW + cosine schedule, checkpointing) and verify decode quality afterwards.

    PYTHONPATH=src python examples/train_small.py [--arch gemma2-27b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.data import pipeline
from repro.launch import steps
from repro.models.api import build_model
from repro.optim import adamw
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_arch(args.arch).reduced(), vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"training reduced {cfg.name}: {n/1e6:.2f}M params")

    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=10,
                                total_steps=args.steps)
    train_step = jax.jit(steps.make_train_step(model, opt_cfg))
    opt_state = adamw.init(params)
    data = pipeline.lm_stream(pipeline.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=48, batch_size=8))
    first = last = None
    for i, batch in zip(range(args.steps), data):
        params, opt_state, m = train_step(params, opt_state, batch)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if i % 25 == 0:
            print(f"  step {i:4d} loss={last:.4f}")
    print(f"loss {first:.3f} -> {last:.3f}")

    path = "experiments/train_small_ckpt"
    ckpt.save(path, params, step=args.steps)
    params2 = ckpt.restore(path, jax.tree.map(jnp.zeros_like, params))
    print(f"checkpoint roundtrip ok: {path}.npz")

    eng = Engine(model, params2, make_policy("lethe", capacity=32))
    res = eng.generate({"tokens": next(data)["tokens"][:2, :32]}, 32)
    print(f"post-restore generation: {res.tokens.shape} tokens at "
          f"{res.tokens_per_second:.0f} tok/s, cache "
          f"{res.cache_bytes/2**20:.2f} MiB")


if __name__ == "__main__":
    main()
