"""Figure 3 reproduction: visualise Lethe's layer- and time-adaptive pruning.

Decodes with a small model and, every few steps, dumps which token positions
each layer retains — the paper's Fig. 3 shows exactly this: different layers
keep different tokens, retained sets mix salient history with the recent
window, and the map changes over time.

    PYTHONPATH=src python examples/visualize_pruning.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model


def retention_map(state, max_pos: int) -> np.ndarray:
    """[L, max_pos] 0/1 — token positions currently held per layer (row 0)."""
    pos = np.asarray(state.pos)          # [L, B, C]
    L = pos.shape[0]
    out = np.zeros((L, max_pos), np.int8)
    for l in range(L):
        live = pos[l, 0][pos[l, 0] >= 0]
        out[l, live[live < max_pos]] = 1
    return out


def render(m: np.ndarray, step: int) -> str:
    rows = [f"step {step:4d}  (█=retained, ·=evicted; columns = positions)"]
    for l, row in enumerate(m):
        rows.append(f"  L{l}: " + "".join("█" if x else "·" for x in row))
    return "\n".join(rows)


def main():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = make_policy("lethe", capacity=24, sink_len=3, sparse_ratio=3.0,
                      recent_ratio=0.25, target_fill=0.5)

    S0, gen = 20, 72
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S0), 0,
                              cfg.vocab_size)
    logits, state = model.prefill(params, {"tokens": toks}, pol)
    tok = jnp.argmax(logits, -1)
    snaps = []
    for t in range(gen):
        logits, state = model.decode_step(params, state, tok,
                                          jnp.asarray(S0 + t), pol)
        tok = jnp.argmax(logits, -1)
        if t % 24 == 23:
            m = retention_map(state, S0 + t + 1)
            snaps.append((S0 + t, m))
            print(render(m, S0 + t), "\n")

    # the paper's qualitative claims, as assertions:
    last = snaps[-1][1]
    assert (last[:, :pol.sink_len].all()), "sinks must always be retained"
    assert last[:, -1].all(), "the newest token must always be retained"
    per_layer = last.sum(1)
    print("retained per layer:", per_layer.tolist())
    if len(set(per_layer.tolist())) > 1:
        print("=> layers retain different budgets (spatial adaptivity)")
    a, b = snaps[0][1], snaps[-1][1]
    overlap = (a[:, :a.shape[1]] & b[:, :a.shape[1]]).sum()
    print(f"retained-set overlap step {snaps[0][0]} vs {snaps[-1][0]}: "
          f"{overlap} positions (temporal adaptivity: sets evolve)")


if __name__ == "__main__":
    main()
