"""Quickstart: build a model, generate with FullKV vs Lethe, watch the cache
stay bounded.

Generation is EOS-aware: pass ``eos_id=<token>`` to ``Engine.generate`` /
``generate_scan`` and each row stops at its first EOS (decode terminates
early once every row is done; see README.md).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine


def main():
    # any of the 10 assigned architectures; reduced() = CPU-sized variant
    cfg = get_arch("mixtral-8x7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                           0, cfg.vocab_size)}

    print(f"model: {cfg.name} ({cfg.family}), reduced to "
          f"{cfg.n_layers}L d={cfg.d_model}")

    full = Engine(model, params, make_policy("fullkv", capacity=160))
    lethe = Engine(model, params, make_policy(
        "lethe", capacity=48, sink_len=4, sparse_ratio=4.0,
        recent_ratio=0.3))

    for name, eng in [("FullKV", full), ("Lethe", lethe)]:
        res = eng.generate(prompt, 96, trace_live=True)
        tr = res.live_token_trace
        print(f"{name:8s} cache={res.cache_bytes/2**20:6.2f} MiB  "
              f"tokens/s={res.tokens_per_second:7.1f}  "
              f"live tokens start={tr[0]} peak={max(tr)} end={tr[-1]}")
    print("Lethe's live-token count plateaus; FullKV grows linearly —"
          " that is the paper, in one print statement.")


if __name__ == "__main__":
    main()
