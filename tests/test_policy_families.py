"""LazyEviction + G-KV policy families, and the typed policy-config errors.

Unit-level coverage of the two decode-time eviction rivals added next to the
paper grid (integration — continuous==solo, chunked==whole, int8, preempt/
resume — rides the existing parametrized batteries):

  * G-KV (arXiv 2512.00504): ranks on age-normalised *global* attention
    mass (γ=1 accumulation / observation age), so an old token favoured by
    raw H2O accumulation loses to a young token with a higher per-step
    share.
  * LazyEviction (arXiv 2506.15969): lagged two-phase eviction encoded in
    the per-row (budget, evict_at) pair — reach budget → keep everything
    and observe for ``lag_window`` steps → then evict by heavy-hitter rank,
    letting recurring reasoning tokens regain score in between.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_lib
from repro.core import pruning, rasr
from repro.core.policy import (GKV, KINDS, LAZYEVICTION, PolicyConfig,
                               fullkv, gkv, lazyeviction, make_policy)


# --------------------------------------------------------------------------
# Policy config: registration + typed rejection of invalid input
# --------------------------------------------------------------------------

def test_new_kinds_registered():
    assert LAZYEVICTION in KINDS and GKV in KINDS
    assert make_policy("lazyeviction", 64).kind == LAZYEVICTION
    assert make_policy("gkv", 64).kind == GKV
    # G-KV accumulates undecayed global attention mass (γ=1 by preset)
    assert gkv(64).gamma == 1.0
    assert lazyeviction(64, lag_window=7).lag_window == 7


def test_make_policy_unknown_kind_typed_error():
    with pytest.raises(ValueError, match="valid kinds are .*lethe"):
        make_policy("h20", 64)                      # typo'd kind
    with pytest.raises(ValueError, match="unknown policy kind"):
        make_policy("", 64)


def test_policyconfig_unknown_kind_typed_error():
    with pytest.raises(ValueError, match="unknown policy kind 'nope'"):
        PolicyConfig(kind="nope")


def test_fullkv_rejects_typoed_kwargs():
    with pytest.raises(ValueError, match="snik_len"):
        fullkv(64, snik_len=2)                      # typo must not vanish
    # valid-but-irrelevant fields are still silently dropped ...
    assert fullkv(64, sparse_ratio=8.0).sparse_ratio != 8.0
    # ... while the fields FullKV does honour pass through
    assert fullkv(64, sink_len=7).sink_len == 7
    assert fullkv(64, kv_format="int8").kv_format == "int8"


def test_make_policy_rejects_typoed_kwargs():
    with pytest.raises(TypeError):
        make_policy("lethe", 64, lag_windw=4)


# --------------------------------------------------------------------------
# decide_row helpers
# --------------------------------------------------------------------------

def _row(C=16, n_valid=10, base_score=0.01):
    pos = np.full(C, -1, np.int32)
    pos[:n_valid] = np.arange(n_valid)
    scores = np.full(C, 0.0, np.float32)
    scores[:n_valid] = base_score
    return pos, scores


def _decide(scores, pos, n_valid, policy, budget, evict_at):
    return pruning.decide_row(
        jnp.asarray(scores), jnp.asarray(pos), jnp.int32(n_valid),
        jnp.int32(n_valid - 1), policy=policy,
        budget=jnp.int32(budget), evict_at=jnp.int32(evict_at))


# --------------------------------------------------------------------------
# G-KV: age-normalised ranking beats raw accumulation
# --------------------------------------------------------------------------

def test_gkv_age_normalisation_flips_h2o_ranking():
    # Token A (pos 1) is old with a big accumulated score; token B (pos 7)
    # is young with a smaller total but a larger per-step share. With one
    # heavy-hitter seat, H2O keeps A; G-KV keeps B.
    pos, scores = _row(n_valid=10)
    scores[1] = 5.0          # A: age 9 -> share 5/9 ~ 0.56
    scores[7] = 3.0          # B: age 3 -> share 3/3 = 1.0
    kw = dict(capacity=16, sink_len=0, recent_ratio=0.3)
    budget = 2               # protected = last token only -> n_hh = 1
    keep_h2o = np.asarray(_decide(
        scores, pos, 10, make_policy("h2o", **kw), budget, budget).keep)
    keep_gkv = np.asarray(_decide(
        scores, pos, 10, make_policy("gkv", **kw), budget, budget).keep)
    assert keep_h2o[1] and not keep_h2o[7]
    assert keep_gkv[7] and not keep_gkv[1]
    assert keep_h2o.sum() == keep_gkv.sum() == budget


def test_gkv_global_scores_helper():
    pos = jnp.asarray([0, 4, 9, -1])
    score = jnp.asarray([10.0, 10.0, 10.0, 10.0])
    g = np.asarray(rasr.global_scores(score, pos, jnp.int32(9)))
    np.testing.assert_allclose(g[:3], [1.0, 10 / 6, 10.0])  # ages 10, 6, 1
    assert np.isfinite(g).all()


# --------------------------------------------------------------------------
# LazyEviction: defer-then-evict two-phase machinery
# --------------------------------------------------------------------------

def test_lazyeviction_defers_then_evicts():
    pol = make_policy("lazyeviction", capacity=16, sink_len=2,
                      lag_window=4)
    pos, scores = _row(n_valid=12)
    scores[:12] = np.linspace(1.0, 0.1, 12)
    budget = 6
    # phase 1: trigger at the budget boundary -> observe, nothing evicted
    d1 = _decide(scores, pos, 12, pol, budget, evict_at=budget)
    assert np.asarray(d1.keep).sum() == 12
    assert int(d1.new_evict_at) == budget + 4
    # phase 2: the lagged trigger -> heavy-hitter eviction down to budget,
    # observation re-armed
    d2 = _decide(scores, pos, 12, pol, budget, evict_at=budget + 4)
    assert np.asarray(d2.keep).sum() == budget
    assert int(d2.new_evict_at) == budget


def test_lazyeviction_lag_clipped_to_capacity():
    pol = make_policy("lazyeviction", capacity=16, sink_len=2,
                      lag_window=1000)
    pos, scores = _row(n_valid=12)
    d = _decide(scores, pos, 12, pol, 6, evict_at=6)
    # the observation window cannot outrun the cache: the 15/16·C capacity
    # backstop in prune_layer fires first
    assert int(d.new_evict_at) == pol.capacity


def _mk_lazy_layer(pol, n_valid, budget):
    c = cache_lib.init_cache(n_layers=1, batch=2, n_kv_heads=2,
                             capacity=pol.capacity, d_head=8, policy=pol,
                             dtype=jnp.float32)
    lay = c.layer(0)
    key = jax.random.PRNGKey(0)
    for t in range(n_valid):
        kn = jax.random.normal(jax.random.fold_in(key, t), (2, 2, 8))
        lay = cache_lib.append_token(lay, kn, kn, t, 1.0)
    return dataclasses.replace(
        lay, budget=jnp.full((2,), budget, jnp.int32),
        evict_at=jnp.full((2,), budget, jnp.int32))


def test_lazyeviction_prune_layer_sawtooth():
    pol = make_policy("lazyeviction", capacity=16, sink_len=2,
                      lag_window=4)
    lay = _mk_lazy_layer(pol, n_valid=12, budget=6)
    cur = jnp.int32(11)
    # round 1: occupancy >= budget triggers, but eviction is deferred
    r1 = pruning.prune_layer(lay, cur, policy=pol)
    assert (np.asarray(r1.length) == 12).all()
    assert (np.asarray(r1.evict_at) == 10).all()
    # round 2: the lagged threshold fires -> compacted down to budget
    r2 = pruning.prune_layer(r1, cur, policy=pol)
    assert (np.asarray(r2.length) == 6).all()
    assert (np.asarray(r2.evict_at) == 6).all()
    # survivors keep the sinks and the most recent token
    pos = np.asarray(r2.pos)
    for b in range(2):
        live = set(pos[b][pos[b] >= 0].tolist())
        assert {0, 1, 11} <= live


def test_lazyeviction_observation_rescues_recurring_token():
    """The policy's reason to exist: a token that is cold when the budget
    is first hit but re-attended during the observation window survives the
    lagged eviction — the same scores evicted immediately (H2O) drop it."""
    pol = make_policy("lazyeviction", capacity=16, sink_len=2,
                      lag_window=4, gamma=1.0)
    lay = _mk_lazy_layer(pol, n_valid=12, budget=6)
    x = 5                                     # the recurring token's slot
    scores = np.full((2, 16), 0.0, np.float32)
    scores[:, :12] = np.linspace(1.0, 0.5, 12)
    scores[:, x] = 0.01                       # cold at the budget boundary
    lay = dataclasses.replace(lay, score=jnp.asarray(scores))

    h2o_keep = np.asarray(_decide(scores[0], np.asarray(lay.pos)[0], 12,
                                  make_policy("h2o", capacity=16,
                                             sink_len=2),
                                  6, 6).keep)
    assert not h2o_keep[x]                    # immediate eviction drops it

    r1 = pruning.prune_layer(lay, jnp.int32(11), policy=pol)   # deferred
    # during the observation window the token is re-attended hard
    bump = jnp.zeros((2, 16)).at[:, x].set(3.0)
    r1 = rasr.update_scores(r1, bump, gamma=pol.gamma)
    r2 = pruning.prune_layer(r1, jnp.int32(11), policy=pol)    # eviction
    pos = np.asarray(r2.pos)
    for b in range(2):
        assert 5 in pos[b][pos[b] >= 0].tolist()
        assert np.asarray(r2.length)[b] == 6
