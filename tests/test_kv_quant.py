"""Bytes-neutral quantized KV cache (DESIGN.md §Quantization).

Battery:
  * quantization round-trip error bounds — per-(token, head) worst case is
    scale/2 = amax/254; all-zero vectors round-trip exactly;
  * bf16 path bit-identity after the refactor — the dense cache flattens to
    the exact pre-refactor 8 leaves, and dense kernels/oracles take the
    scale-free code path (None scales change nothing);
  * int8 kernel equivalence — the in-kernel VMEM dequant (Pallas interpret)
    matches the int8 oracle, and the int8 oracle is *bitwise* the dense
    oracle run on host-dequantised values;
  * differential ``generate``/``generate_scan`` int8-vs-bf16 across
    lethe/h2o/streaming within a stated tolerance, with the two int8
    drivers token-identical;
  * ``compact``/slot-refill scale coherence — every survivor's
    (payload, scale, pos, score) tuple moves as one unit (hypothesis fuzz
    with a seeded fallback sweep);
  * chunked prefill on the quantized layout — 2x-capacity prompts admit
    compressed and stay decodable;
  * config-time validation — recurrent families and unknown formats fail
    fast with clear errors;
  * physical-bytes accounting — int8 payload+scales ≤ 55% of the bf16
    payload at Dh = 64, and the engine/Completion metrics surface it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import cache as cache_lib
from repro.core.policy import lethe, make_policy
from repro.kernels import ref
from repro.kernels.decode_attention import (GLOBAL_WINDOW,
                                            decode_attention_pallas,
                                            live_lengths)
from repro.models.api import build_model
from repro.serving.engine import Engine

# --------------------------------------------------------------------------
# Quantization primitive: round-trip error bounds
# --------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(3, 2, 64), (2, 4, 16, 32), (5, 8)])
def test_quantize_roundtrip_error_bound_per_head(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3.0
    q, s = cache_lib.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == shape[:-1]
    xr = cache_lib.dequantize_kv(q, s)
    # worst-case rounding error per element is half a quantization step,
    # i.e. scale/2 per (token, head) vector — assert it per vector
    err = np.abs(np.asarray(xr) - np.asarray(x)).max(axis=-1)
    bound = np.asarray(s) / 2 + 1e-7
    assert (err <= bound).all(), (err.max(), bound.min())
    # and the max element survives exactly up to one step
    amax = np.abs(np.asarray(x)).max(axis=-1)
    assert (err <= amax / 254 + 1e-7).all()


def test_quantize_zero_vectors_roundtrip_exact():
    x = jnp.zeros((2, 3, 16))
    q, s = cache_lib.quantize_kv(x)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(cache_lib.dequantize_kv(q, s)),
                                  0.0)


# --------------------------------------------------------------------------
# bf16 path bit-identity after the refactor
# --------------------------------------------------------------------------


def test_dense_cache_pytree_unchanged():
    """kv_format='bf16' must flatten to the exact pre-refactor leaf set:
    no scale leaves, same field order — donation aliases, sharding specs
    and checkpoints of the dense path are untouched."""
    pol = lethe(capacity=16)
    c = cache_lib.init_cache(n_layers=1, batch=2, n_kv_heads=2, capacity=16,
                             d_head=8, policy=pol, dtype=jnp.float32)
    leaves = jax.tree.leaves(c)
    assert len(leaves) == 8
    assert not c.quantized and c.k_scale is None and c.v_scale is None
    assert c.k.dtype == jnp.float32


def test_dense_oracle_ignores_scale_kwargs():
    """None scales must be the identity code path (the bf16 hot path
    traces the same program as before the refactor)."""
    B, Hq, Hkv, C, Dh = 2, 4, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)
    score = jax.random.uniform(ks[3], (B, C))
    a = ref.decode_attention_fused_ref(q, k, v, pos, C - 1, score,
                                       gamma=0.9, scale=Dh ** -0.5)
    b = ref.decode_attention_fused_ref(q, k, v, pos, C - 1, score,
                                       gamma=0.9, scale=Dh ** -0.5,
                                       k_scale=None, v_scale=None)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# int8 kernel equivalence
# --------------------------------------------------------------------------


def _quantized_layer_inputs(key, B, Hq, Hkv, C, Dh, lives):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    kd = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    vd = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.stack([jnp.where(jnp.arange(C) < n, jnp.arange(C), -1)
                     for n in lives]).astype(jnp.int32)
    score = jnp.where(pos >= 0, jax.random.uniform(ks[3], (B, C)), 0.0)
    kq, ksc = cache_lib.quantize_kv(kd)
    vq, vsc = cache_lib.quantize_kv(vd)
    return q, kq, ksc, vq, vsc, pos, score


@pytest.mark.parametrize("lives,window", [
    ([1, 1], None), ([37, 99], None), ([128, 128], None), ([64, 128], 48)])
def test_int8_kernel_matches_int8_oracle(lives, window):
    B, Hq, Hkv, C, Dh = 2, 8, 2, 128, 32
    q, kq, ksc, vq, vsc, pos, score = _quantized_layer_inputs(
        jax.random.PRNGKey(2), B, Hq, Hkv, C, Dh, lives)
    lens = live_lengths(pos)
    cur = lens - 1
    o_r, ps_r, ns_r = ref.decode_attention_fused_ref(
        q, kq, vq, pos, cur, score, gamma=0.95, window=window,
        scale=Dh ** -0.5, k_scale=ksc, v_scale=vsc)
    win = GLOBAL_WINDOW if window is None else window
    o_p, ps_p, ns_p, blocks = decode_attention_pallas(
        q, kq, vq, pos, score, lens, cur, jnp.int32(win), scale=Dh ** -0.5,
        gamma=0.95, block_c=32, interpret=True, k_scale=ksc, v_scale=vsc)
    assert np.abs(np.asarray(o_p) - np.asarray(o_r)).max() <= 1e-5
    assert np.abs(np.asarray(ps_p) - np.asarray(ps_r)).max() <= 1e-5
    assert np.abs(np.asarray(ns_p) - np.asarray(ns_r)).max() <= 1e-5
    # early exit still tracks live tokens on the int8 path
    expected = np.maximum(-(-np.asarray(lives) // 32), 1)
    np.testing.assert_array_equal(
        np.asarray(blocks), np.broadcast_to(expected[:, None], (B, Hkv)))


def test_int8_oracle_is_dequant_dense_oracle_bitwise():
    """The int8 oracle must be *exactly* the dense oracle run on
    host-dequantised values — in-kernel dequant changes where the multiply
    happens, not what is computed."""
    B, Hq, Hkv, C, Dh = 2, 4, 2, 64, 16
    q, kq, ksc, vq, vsc, pos, score = _quantized_layer_inputs(
        jax.random.PRNGKey(3), B, Hq, Hkv, C, Dh, [40, 64])
    cur = live_lengths(pos) - 1
    a = ref.decode_attention_fused_ref(q, kq, vq, pos, cur, score,
                                       gamma=0.9, scale=Dh ** -0.5,
                                       k_scale=ksc, v_scale=vsc)
    b = ref.decode_attention_fused_ref(
        q, cache_lib.dequantize_kv(kq, ksc),
        cache_lib.dequantize_kv(vq, vsc), pos, cur, score,
        gamma=0.9, scale=Dh ** -0.5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_int8_flash_prefill_matches_oracle():
    from repro.kernels.flash_prefill import flash_prefill_pallas
    B, Hq, Hkv, S, Dh = 1, 4, 2, 48, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    kd = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    vd = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    kq, ksc = cache_lib.quantize_kv(kd)
    vq, vsc = cache_lib.quantize_kv(vd)
    out, _ = flash_prefill_pallas(q, kq, vq, scale=Dh ** -0.5, causal=True,
                                  block_q=16, block_k=16, interpret=True,
                                  k_scale=ksc, v_scale=vsc)
    exp, _ = ref.prefill_attention_ref(
        q, cache_lib.dequantize_kv(kq, ksc),
        cache_lib.dequantize_kv(vq, vsc), causal=True, scale=Dh ** -0.5)
    assert np.abs(np.asarray(out) - np.asarray(exp)).max() <= 1e-5


# --------------------------------------------------------------------------
# Differential generate / generate_scan across policies
# --------------------------------------------------------------------------


def _tiny_setup(vocab=128):
    cfg = dataclasses.replace(
        get_arch("granite-20b").reduced(), n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=vocab)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, vocab)
    return cfg, model, params, toks


@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming",
                                  "lazyeviction", "gkv"])
def test_generate_int8_vs_dense_differential(kind):
    """Stated tolerance: int8 prefill logits within 0.08 abs of dense
    (random init, |logits| ~ O(1)), ≥ 70% greedy-token agreement over a
    20-step decode, and the two int8 drivers (Python-stepped vs scanned)
    token-identical."""
    cfg, model, params, toks = _tiny_setup()
    pol_d = make_policy(kind, capacity=24)
    pol_q = dataclasses.replace(pol_d, kv_format="int8")
    eng_d = Engine(model, params, pol_d)
    eng_q = Engine(model, params, pol_q)

    lg_d, _ = eng_d.prefill({"tokens": toks})
    lg_q, _ = eng_q.prefill({"tokens": toks})
    assert np.abs(np.asarray(lg_d) - np.asarray(lg_q)).max() <= 0.08

    rd = eng_d.generate({"tokens": toks}, 20)
    rq = eng_q.generate({"tokens": toks}, 20)
    rqs = eng_q.generate_scan({"tokens": toks}, 20)
    np.testing.assert_array_equal(rq.tokens, rqs.tokens)   # driver identity
    agreement = float(np.mean(rd.tokens == rq.tokens))
    assert agreement >= 0.7, agreement
    assert rq.kv_format == "int8" and rd.kv_format == "bf16"
    assert rq.cache_bytes < rd.cache_bytes


def test_int8_multi_round_pruning_stays_coherent():
    """Long decode through several prune rounds: occupancy bounded by
    capacity, scores finite, scales strictly positive on live slots."""
    cfg, model, params, toks = _tiny_setup()
    pol = lethe(capacity=20, kv_format="int8", sparse_ratio=3.0)
    eng = Engine(model, params, pol)
    r = eng.generate({"tokens": toks}, 40, trace_live=True)
    assert r.steps == 40
    _, state = eng.prefill({"tokens": toks})
    assert int(np.asarray(state.length).max()) <= 20
    live = np.asarray(state.pos) >= 0                    # [L, B, C]
    ksc = np.asarray(state.k_scale)                      # [L, B, Hkv, C]
    assert (ksc[np.broadcast_to(live[:, :, None, :], ksc.shape)] > 0).all()


# --------------------------------------------------------------------------
# compact / slot-refill scale coherence (fuzzed)
# --------------------------------------------------------------------------


def _coherence_case(seed: int) -> None:
    """Random appends then a random keep-mask compaction: every survivor's
    dequantised K/V must equal its pre-compact dequantised value, matched
    by position — payloads and scales move as one unit."""
    rng = np.random.default_rng(seed)
    B, Hkv, C, Dh = int(rng.integers(1, 4)), 2, 24, 8
    n_tok = int(rng.integers(1, C))
    pol = lethe(capacity=C, kv_format="int8")
    lay = cache_lib.init_cache(n_layers=1, batch=B, n_kv_heads=Hkv,
                               capacity=C, d_head=Dh, policy=pol).layer(0)
    key = jax.random.PRNGKey(seed)
    for t in range(n_tok):
        kn = jax.random.normal(jax.random.fold_in(key, t), (B, Hkv, Dh))
        lay = cache_lib.append_token(lay, kn, kn * 0.5 + 1.0, t, 1.0)
    keep = jnp.asarray(rng.random((B, C)) > rng.uniform(0.1, 0.7))
    comp = cache_lib.compact(lay, keep)
    pre_k = np.asarray(cache_lib.dequantize_kv(lay.k, lay.k_scale))
    pre_v = np.asarray(cache_lib.dequantize_kv(lay.v, lay.v_scale))
    post_k = np.asarray(cache_lib.dequantize_kv(comp.k, comp.k_scale))
    post_v = np.asarray(cache_lib.dequantize_kv(comp.v, comp.v_scale))
    pos_pre, pos_post = np.asarray(lay.pos), np.asarray(comp.pos)
    for b in range(B):
        for c in range(int(comp.length[b])):
            p = pos_post[b, c]
            src = int(np.where(pos_pre[b] == p)[0][0])
            np.testing.assert_array_equal(post_k[b, :, c], pre_k[b, :, src])
            np.testing.assert_array_equal(post_v[b, :, c], pre_v[b, :, src])
            assert comp.score[b, c] == lay.score[b, src]


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000))
    def test_compact_scale_coherence_fuzz(seed):
        _coherence_case(seed)
except ImportError:
    pass                                     # seeded sweep below still runs


@pytest.mark.parametrize("seed", [0, 1, 2, 7, 13, 42, 1234])
def test_compact_scale_coherence_seeded(seed):
    _coherence_case(seed)


def test_slot_refill_scale_coherence():
    """insert_slot / reset_slot on a quantized live state: the addressed
    row carries its scales in; every other row — payloads AND scales —
    passes through bit-identically."""
    pol = lethe(capacity=16, kv_format="int8")
    state = cache_lib.init_cache(n_layers=2, batch=3, n_kv_heads=2,
                                 capacity=16, d_head=8, policy=pol)
    key = jax.random.PRNGKey(9)
    # populate all rows via per-layer appends
    for t in range(6):
        for l in range(2):
            kn = jax.random.normal(jax.random.fold_in(key, 10 * l + t),
                                   (3, 2, 8))
            lay = cache_lib.append_token(state.layer(l), kn, kn, t, 1.0)
            state = jax.tree.map(
                lambda full, one, l=l: full.at[l].set(one), state, lay)
    row = cache_lib.init_cache(n_layers=2, batch=1, n_kv_heads=2,
                               capacity=16, d_head=8, policy=pol)
    rn = jax.random.normal(jax.random.fold_in(key, 99), (1, 2, 8))
    for l in range(2):
        lay = cache_lib.append_token(row.layer(l), rn, rn, 0, 1.0)
        row = jax.tree.map(lambda full, one, l=l: full.at[l].set(one),
                           row, lay)
    new = cache_lib.insert_slot(state, 1, row)
    for b in (0, 2):     # neighbors bit-identical, scales included
        np.testing.assert_array_equal(np.asarray(new.k[:, b]),
                                      np.asarray(state.k[:, b]))
        np.testing.assert_array_equal(np.asarray(new.k_scale[:, b]),
                                      np.asarray(state.k_scale[:, b]))
    np.testing.assert_array_equal(np.asarray(new.k[:, 1]),
                                  np.asarray(row.k[:, 0]))
    np.testing.assert_array_equal(np.asarray(new.k_scale[:, 1]),
                                  np.asarray(row.k_scale[:, 0]))
    # retire it again: scales reset to the empty-slot value, others intact
    reset = cache_lib.reset_slot(new, 1)
    np.testing.assert_array_equal(np.asarray(reset.k_scale[:, 1]), 1.0)
    np.testing.assert_array_equal(np.asarray(reset.k_scale[:, 0]),
                                  np.asarray(new.k_scale[:, 0]))


# --------------------------------------------------------------------------
# Chunked prefill on the quantized layout
# --------------------------------------------------------------------------


def test_chunked_prefill_int8_compresses_and_decodes():
    cfg, model, params, _ = _tiny_setup()
    pol = lethe(capacity=24, kv_format="int8")
    long_toks = jax.random.randint(jax.random.PRNGKey(5), (1, 50), 0, 128)
    logits, state = model.prefill_chunked(
        params, {"tokens": long_toks}, pol, chunk_plan=(16, 16, 16, 2))
    assert state.quantized and state.k.dtype == jnp.int8
    assert int(np.asarray(state.length).max()) <= 24
    # the compressed quantized cache must decode
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg, state = model.decode_step(params, state, tok, jnp.int32(50), pol)
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_chunked_vs_whole_int8_within_tolerance():
    """Chunked admission reads the quantized prefix mid-prefill while the
    whole-prompt path computes on exact values and quantizes at fill — the
    two agree to quantization tolerance (bit-identity is a bf16-path
    guarantee, already enforced by test_chunked_prefill)."""
    cfg, model, params, toks = _tiny_setup()
    pol = lethe(capacity=24, kv_format="int8")
    lg_c, st_c = model.prefill_chunked(params, {"tokens": toks}, pol,
                                       chunk_plan=(8, 4))
    lg_w, st_w = model.prefill(params, {"tokens": toks}, pol)
    assert np.abs(np.asarray(lg_c) - np.asarray(lg_w)).max() <= 0.08
    np.testing.assert_array_equal(np.asarray(st_c.pos), np.asarray(st_w.pos))


# --------------------------------------------------------------------------
# Config-time validation
# --------------------------------------------------------------------------


def test_kv_format_rejected_for_recurrent_families():
    pol = lethe(capacity=16, kv_format="int8")
    for arch in ("rwkv6-7b", "recurrentgemma-2b"):
        model = build_model(get_arch(arch).reduced())
        with pytest.raises(ValueError, match="int8"):
            Engine(model, None, pol)
        with pytest.raises(ValueError, match="int8"):
            model.init_decode_state(pol, 2)


def test_unknown_kv_format_rejected():
    with pytest.raises(ValueError, match="kv_format"):
        make_policy("lethe", capacity=16, kv_format="fp4")


@pytest.mark.parametrize("kind", ["fullkv", "pyramidkv"])
def test_all_cache_policies_accept_int8(kind):
    cfg, model, params, toks = _tiny_setup()
    pol = make_policy(kind, capacity=24, kv_format="int8")
    assert pol.kv_format == "int8"
    eng = Engine(model, params, pol)
    r = eng.generate({"tokens": toks}, 6)
    assert r.kv_format == "int8" and r.steps == 6


# --------------------------------------------------------------------------
# Physical-bytes accounting
# --------------------------------------------------------------------------


def test_int8_halves_kv_bytes_at_dh64():
    """Acceptance arithmetic at the benchmark shape (Dh=64): int8 payload
    plus f32 per-(token, head) scales ≤ 55% of the bf16 payload bytes."""
    kw = dict(n_layers=2, batch=2, n_kv_heads=2, capacity=64, d_head=64)
    dense = cache_lib.init_cache(policy=lethe(capacity=64),
                                 dtype=jnp.bfloat16, **kw)
    quant = cache_lib.init_cache(policy=lethe(capacity=64,
                                              kv_format="int8"), **kw)
    d = dense.memory_breakdown()
    q = quant.memory_breakdown()
    ratio = (q["kv_payload_bytes"] + q["scale_bytes"]) / d["kv_payload_bytes"]
    assert ratio <= 0.55, ratio
    assert quant.memory_bytes() == sum(q.values())


def test_engine_and_completion_surface_physical_bytes():
    from repro.serving.engine import _cache_stats
    from repro.serving.scheduler import Request, Scheduler
    cfg, model, params, toks = _tiny_setup()
    eng = Engine(model, params, lethe(capacity=24, kv_format="int8"))
    state = eng.new_decode_state(2)
    stats = _cache_stats(state)
    assert stats["kv_format"] == "int8"
    assert stats["cache_bytes"] == sum(
        stats["cache_bytes_breakdown"].values())
    assert stats["cache_bytes_breakdown"]["scale_bytes"] > 0
    sched = Scheduler(eng, batch_slots=2, segment_len=4)
    sched.submit([Request(uid=0, prompt=np.asarray(toks)[0],
                          max_new_tokens=4)])
    done = sched.run()
    assert done[0].kv_format == "int8"
    assert done[0].cache_bytes == stats["cache_bytes"]
