"""Preempt/resume interleaving fuzz.

Hypothesis (with a seeded fallback sweep) over random preemption points ×
admission orders × policies: no matter when residents are snapshotted to
host and resumed, every request's tokens are bit-identical to an
uninterrupted solo run, every uid completes exactly once, and each
preemption leaves the surviving slots' rows — RASR scores included —
bit-identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, FrontDoorCore,
                                     ServeRequest)

INF = float("inf")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(uid=i,
                         prompt=rng.integers(0, cfg.vocab_size,
                                             size=s).astype(np.int32),
                         max_new_tokens=n)
            for i, (s, n) in enumerate(spec)]


def _solo(engine, req):
    res = engine.generate({"tokens": jnp.asarray(req.prompt)[None, :]},
                          req.max_new_tokens)
    return np.asarray(res.tokens[0, :res.gen_lens[0]])


def _neighbor_rows(state, skip_slot):
    rows = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        rows[jax.tree_util.keystr(path)] = np.delete(
            np.asarray(leaf), skip_slot, axis=1)
    return rows


def _fuzz_case(setup, policy, spec, slots, order_seed, preempt_seed):
    """One interleaving: submit in a shuffled order, then at every segment
    boundary preempt a random subset of live residents (snapshot-to-host +
    requeue) before stepping. Invariants: tokens == solo, exactly-once
    completion, neighbor rows untouched by each preempt."""
    cfg, model, params = setup
    pol = make_policy(policy, capacity=16, sink_len=2, sparse_ratio=3.0,
                      target_fill=0.5)
    eng = Engine(model, params, pol)
    reqs = _reqs(cfg, spec, seed=len(spec))
    solo = {r.uid: _solo(eng, r) for r in reqs}

    order = list(reqs)
    np.random.default_rng(order_seed).shuffle(order)
    core = FrontDoorCore(eng, batch_slots=slots, segment_len=3,
                         admission=AdmissionConfig(compress_at=INF,
                                                   shed_at=INF,
                                                   reject_at=INF))
    core.submit(order)
    rng = np.random.default_rng(preempt_seed)
    forced, steps = 0, 0
    while not core.idle:
        steps += 1
        assert steps < 500, "front door failed to drain"
        live = [i for i in range(slots) if core.slots[i] is not None]
        for i in live:
            # cap forced churn so the loop always makes progress
            if core.slots[i] is not None and forced < 12 \
                    and rng.random() < 0.4:
                before = _neighbor_rows(core.state, i)
                core.preempt_slot(i)
                after = _neighbor_rows(core.state, i)
                for name, arr in before.items():
                    np.testing.assert_array_equal(arr, after[name],
                                                  err_msg=name)
                forced += 1
        core.step()

    done = core.run()
    assert [c.uid for c in done] == list(range(len(reqs)))  # exactly once
    for c in done:
        np.testing.assert_array_equal(
            np.asarray(c.tokens), solo[c.uid],
            err_msg=f"uid {c.uid} after {forced} preemptions")
    assert core.run_summary()["preempted"] == forced
    assert not core.queue


# prompt lengths from a small set so jit compiles stay bounded
_LENS, _MAXNEW = (4, 6, 9), (2, 12)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    _REQ = st.tuples(st.sampled_from(_LENS), st.integers(*_MAXNEW))

    @settings(max_examples=6, deadline=None)
    @given(st.sampled_from(["lethe", "h2o", "streaming",
                            "lazyeviction", "gkv"]),
           st.lists(_REQ, min_size=2, max_size=6),
           st.sampled_from([2, 3]),
           st.integers(0, 2 ** 16),
           st.integers(0, 2 ** 16))
    def test_fuzz_preempt_resume(setup, policy, spec, slots, order_seed,
                                 preempt_seed):
        _fuzz_case(setup, policy, spec, slots, order_seed, preempt_seed)
except ImportError:                          # pragma: no cover
    pass                                     # seeded sweep below still runs


@pytest.mark.parametrize("policy,case_seed,slots",
                         [("lethe", 0, 2), ("h2o", 1, 3),
                          ("streaming", 2, 2), ("lethe", 3, 3),
                          ("lazyeviction", 4, 2), ("gkv", 5, 3)])
def test_seeded_preempt_resume(setup, policy, case_seed, slots):
    """Deterministic fallback sweep — runs even without hypothesis."""
    rng = np.random.default_rng(case_seed)
    n = int(rng.integers(2, 7))
    spec = [(int(rng.choice(_LENS)), int(rng.integers(*_MAXNEW) + 1))
            for _ in range(n)]
    _fuzz_case(setup, policy, spec, slots,
               order_seed=case_seed + 100, preempt_seed=case_seed + 200)
