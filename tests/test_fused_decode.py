"""Occupancy-adaptive fused decode hot path (DESIGN.md §2.3, §Perf):

  * early-exit kernel == ref oracle across occupancy levels, and the
    kernel's measured block counter is occupancy-proportional;
  * the in-kernel RASR epilogue matches the standalone
    ``rasr.update_scores`` pass bit-for-bit in f32;
  * one prune round performs exactly one argsort over C per row
    (decide_row sorts once, compact is a sort-free stable partition);
  * ``decode_step`` donates the cache pytree — no second cache copy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_lib
from repro.core import pruning, rasr
from repro.core.policy import make_policy
from repro.kernels import ref
from repro.kernels.decode_attention import (GLOBAL_WINDOW,
                                            decode_attention_pallas,
                                            live_lengths)


def _packed_layer_inputs(key, B, Hq, Hkv, C, Dh, lives):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.stack([jnp.where(jnp.arange(C) < n, jnp.arange(C), -1)
                     for n in lives]).astype(jnp.int32)
    score = jnp.where(pos >= 0, jax.random.uniform(ks[3], (B, C)), 0.0)
    return q, k, v, pos, score


# --------------------------------------------------------------------------
# Early-exit kernel: equivalence + occupancy proportionality
# --------------------------------------------------------------------------

C, BLOCK_C = 256, 32
OCCUPANCY_CASES = [
    # (lives per row, window): empty-but-one, ragged, one block, full
    ([1, 1], None),
    ([37, 203], None),
    ([32, 32], None),
    ([C, C], None),
    ([64, 256], 48),          # sliding window + ragged occupancy
    ([C // 4, C // 4], None),  # the 1/4-occupancy acceptance point
]


@pytest.mark.parametrize("lives,window", OCCUPANCY_CASES)
def test_early_exit_matches_ref_across_occupancy(lives, window):
    B, Hq, Hkv, Dh = 2, 8, 2, 32
    q, k, v, pos, score = _packed_layer_inputs(
        jax.random.PRNGKey(0), B, Hq, Hkv, C, Dh, lives)
    lens = live_lengths(pos)
    cur = lens - 1                 # query at each row's newest position
    gamma = 0.95

    o_ref, ps_ref, ns_ref = ref.decode_attention_fused_ref(
        q, k, v, pos, cur, score, gamma=gamma, window=window,
        scale=Dh ** -0.5)
    win = GLOBAL_WINDOW if window is None else window
    o_pl, ps_pl, ns_pl, blocks = decode_attention_pallas(
        q, k, v, pos, score, lens, cur, jnp.int32(win), scale=Dh ** -0.5,
        gamma=gamma, block_c=BLOCK_C, interpret=True)

    assert np.abs(np.asarray(o_pl) - np.asarray(o_ref)).max() <= 1e-5
    assert np.abs(np.asarray(ps_pl) - np.asarray(ps_ref)).max() <= 1e-5
    assert np.abs(np.asarray(ns_pl) - np.asarray(ns_ref)).max() <= 1e-5

    # The block counter is incremented inside the kernel per executed
    # C-block: work must track live tokens, not capacity.
    expected = np.maximum(-(-np.asarray(lives) // BLOCK_C), 1)
    np.testing.assert_array_equal(
        np.asarray(blocks), np.broadcast_to(expected[:, None], (B, Hkv)))


def test_quarter_occupancy_halves_block_iterations():
    """Acceptance: at 1/4 occupancy the kernel executes ≤ 1/2 of the
    full-capacity C-block iterations."""
    B, Hq, Hkv, Dh = 2, 8, 2, 32
    counts = {}
    for frac in (4, 1):            # C/4 and C
        live = C // frac
        q, k, v, pos, score = _packed_layer_inputs(
            jax.random.PRNGKey(1), B, Hq, Hkv, C, Dh, [live] * B)
        lens = live_lengths(pos)
        *_, blocks = decode_attention_pallas(
            q, k, v, pos, score, lens, lens - 1, jnp.int32(GLOBAL_WINDOW),
            scale=Dh ** -0.5, block_c=BLOCK_C, interpret=True)
        counts[frac] = int(np.asarray(blocks).sum())
    assert counts[4] * 2 <= counts[1], counts


# --------------------------------------------------------------------------
# Fused RASR epilogue vs the standalone update_scores pass
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gamma", [0.95, 1.0])
def test_fused_scores_bit_for_bit_vs_update_scores(gamma):
    B, Hq, Hkv, Dh = 2, 8, 2, 32
    q, k, v, pos, score = _packed_layer_inputs(
        jax.random.PRNGKey(2), B, Hq, Hkv, C, Dh, [77, C])
    lens = live_lengths(pos)
    _, probsum, new_score, _ = decode_attention_pallas(
        q, k, v, pos, score, lens, lens - 1, jnp.int32(GLOBAL_WINDOW),
        scale=Dh ** -0.5, gamma=gamma, block_c=BLOCK_C, interpret=True)

    zeros_kv = jnp.zeros((B, Hkv, C, Dh))
    layer = cache_lib.KVCache(
        k=zeros_kv, v=zeros_kv, pos=pos, score=score, length=lens,
        budget=jnp.full((), C, jnp.int32), evict_at=jnp.full((), C, jnp.int32),
        sparsity=jnp.float32(0.0))
    # jit the old pass exactly as decode_step always ran it: under jit both
    # paths lower γ·score + probsum to the same contracted f32 fma, so the
    # comparison is bit-for-bit (eager dispatch skips the contraction and
    # differs by 1 ulp — a property of op-by-op execution, not of the fusion).
    expected = jax.jit(
        lambda l, p: rasr.update_scores(l, p, gamma))(layer, probsum).score
    np.testing.assert_array_equal(np.asarray(new_score), np.asarray(expected))


# --------------------------------------------------------------------------
# Single-sort prune round
# --------------------------------------------------------------------------

def _subjaxprs(params):
    for v in params.values():
        for x in (v if isinstance(v, (list, tuple)) else [v]):
            name = type(x).__name__
            if name == "ClosedJaxpr":
                yield x.jaxpr
            elif name == "Jaxpr":
                yield x


def _count_sorts(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in ("sort", "top_k", "approx_top_k"):
            n += 1
        for sub in _subjaxprs(eqn.params):
            n += _count_sorts(sub)
    return n


@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming", "pyramidkv",
                                  "lazyeviction", "gkv"])
def test_prune_round_single_sort(kind):
    """One prune round lowers to exactly one sort over C per row: decide_row
    ranks once, every mask is cumsum-derived, compact is sort-free."""
    Cp = 64
    pol = make_policy(kind, capacity=Cp, sink_len=2, sparse_ratio=3.0)
    lay = cache_lib.init_cache(n_layers=1, batch=2, n_kv_heads=2, capacity=Cp,
                               d_head=8, policy=pol,
                               dtype=jnp.float32).layer(0)
    jaxpr = jax.make_jaxpr(
        lambda l: pruning.prune_layer(l, jnp.int32(40), policy=pol,
                                      force=True))(lay)
    assert _count_sorts(jaxpr.jaxpr) == 1, jaxpr


def test_compact_is_sort_free():
    Cp = 64
    pol = make_policy("lethe", capacity=Cp)
    lay = cache_lib.init_cache(n_layers=1, batch=2, n_kv_heads=2, capacity=Cp,
                               d_head=8, policy=pol,
                               dtype=jnp.float32).layer(0)
    keep = lay.pos >= 0
    jaxpr = jax.make_jaxpr(cache_lib.compact)(lay, keep)
    assert _count_sorts(jaxpr.jaxpr) == 0, jaxpr


def test_compact_stable_partition_matches_argsort_semantics():
    """The cumsum stable partition must reproduce the historical
    argsort-by-position compaction on invariant-respecting caches."""
    Cp = 32
    pol = make_policy("lethe", capacity=Cp, sink_len=2)
    lay = cache_lib.init_cache(n_layers=1, batch=2, n_kv_heads=1, capacity=Cp,
                               d_head=4, policy=pol,
                               dtype=jnp.float32).layer(0)
    key = jax.random.PRNGKey(3)
    for t in range(20):
        kn = jax.random.normal(jax.random.fold_in(key, t), (2, 1, 4))
        lay = cache_lib.append_token(lay, kn, kn, t, 1.0)
    keep = (lay.pos % 3 != 1) & (lay.pos >= 0)   # holes in the middle
    out = cache_lib.compact(lay, keep)
    pos = np.asarray(out.pos)
    length = np.asarray(out.length)
    for b in range(2):
        live = pos[b][pos[b] >= 0]
        assert len(live) == length[b]
        assert (pos[b][:length[b]] >= 0).all()
        assert (pos[b][length[b]:] == -1).all()
        assert (np.diff(live) > 0).all()         # increasing positions
        # survivors are exactly the kept positions
        expected = [p for p in range(20) if p % 3 != 1]
        assert live.tolist() == expected
    # K/V rows moved with their positions
    kv = np.asarray(out.k[0, 0, :, 0])
    kin = np.asarray(lay.k[0, 0, :, 0])
    order = [p for p in range(20) if p % 3 != 1]
    np.testing.assert_allclose(kv[:len(order)], kin[order])


# --------------------------------------------------------------------------
# Donated cache buffers
# --------------------------------------------------------------------------

def test_decode_step_donates_cache():
    """Acceptance: decode_step must not allocate a fresh K/V copy — the
    input cache pytree is donated and its buffers deleted after the call."""
    from repro.configs import get_arch
    from repro.models.api import build_model

    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = make_policy("lethe", capacity=16, sink_len=2, sparse_ratio=4.0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    logits, state = model.prefill(params, batch, pol)
    old_k, old_v = state.k, state.v
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    _, state = model.decode_step(params, state, tok, jnp.int32(12), pol)
    assert old_k.is_deleted() and old_v.is_deleted()
    # the new cache is fully usable for the next step
    _, state = model.decode_step(params, state, tok, jnp.int32(13), pol)
    assert bool(jnp.isfinite(jnp.sum(state.score)))
