"""Budget-allocator conservation + Hoyer-sparsity edge cases.

Unlike ``test_core_properties.py`` (which needs ``hypothesis``), this module
always runs: the conservation property is checked over a seeded random sweep,
with an extra hypothesis-driven version when the package is available.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity


def _alloc(spars, *, capacity, nominal, min_budget, sink_len, recent_len):
    return np.asarray(sparsity.allocate_budgets(
        jnp.asarray(np.asarray(spars, np.float32)), capacity=capacity,
        nominal=nominal, min_budget=min_budget, sink_len=sink_len,
        recent_len=recent_len))


def _bounds(*, capacity, min_budget, sink_len, recent_len):
    floor = max(min_budget, sink_len + recent_len + 1)
    ceil = int(capacity * 15 / 16)
    return floor, ceil


# --------------------------------------------------------------------------
# Exact conservation: sum == L * nominal whenever that total is feasible
# --------------------------------------------------------------------------

def test_budget_conservation_exact_seeded_sweep():
    rng = np.random.default_rng(0)
    checked = 0
    for _ in range(200):
        L = int(rng.integers(1, 9))
        cap = int(rng.integers(24, 257))
        nominal = int(rng.integers(8, cap))
        sink = int(rng.integers(0, 6))
        rec = int(rng.integers(1, 12))
        minb = int(rng.integers(1, 20))
        floor, ceil = _bounds(capacity=cap, min_budget=minb,
                              sink_len=sink, recent_len=rec)
        if not (floor <= nominal <= ceil):
            continue                     # infeasible total; covered below
        b = _alloc(rng.random(L), capacity=cap, nominal=nominal,
                   min_budget=minb, sink_len=sink, recent_len=rec)
        assert b.sum() == L * nominal, (L, cap, nominal, floor, ceil, b)
        assert (b >= floor).all() and (b <= ceil).all()
        checked += 1
    assert checked > 50                  # the sweep actually exercised cases


def test_budget_conservation_extreme_sparsity():
    # One dense layer among near-uniform-attention layers used to lose
    # tokens to the int truncation; now the residual is handed back.
    for spars in ([0.0, 0.99, 0.99, 0.99], [0.5] * 7, [1.0, 0.0],
                  [0.3, 0.31, 0.29]):
        b = _alloc(spars, capacity=256, nominal=128, min_budget=16,
                   sink_len=4, recent_len=8)
        assert b.sum() == len(spars) * 128, (spars, b)


def test_budget_infeasible_totals_saturate():
    floor, ceil = _bounds(capacity=64, min_budget=40, sink_len=4,
                          recent_len=8)
    # nominal below the floor: every layer saturates at the floor
    b = _alloc([0.2, 0.8, 0.5], capacity=64, nominal=floor - 8,
               min_budget=40, sink_len=4, recent_len=8)
    assert (b == floor).all()
    # nominal above the ceiling: every layer saturates at the ceiling
    b = _alloc([0.2, 0.8, 0.5], capacity=64, nominal=ceil + 4,
               min_budget=40, sink_len=4, recent_len=8)
    assert (b == ceil).all()


def test_budget_denser_layers_still_get_more():
    # The residual hand-out must not break the allocator's ordering.
    b = _alloc([0.1, 0.9, 0.5], capacity=512, nominal=128, min_budget=8,
               sink_len=2, recent_len=4)
    assert b[0] > b[2] > b[1]
    assert b.sum() == 3 * 128


def test_budget_batched_per_row_conservation():
    rng = np.random.default_rng(1)
    L, B = 5, 4
    sp = jnp.asarray(rng.random((L, B)).astype(np.float32))
    bb = np.asarray(sparsity.allocate_budgets_batched(
        sp, capacity=128, nominal=48, min_budget=8, sink_len=4,
        recent_len=9))
    assert bb.shape == (L, B)
    # conservation is PER REQUEST (per slot), not pooled across the batch
    assert (bb.sum(axis=0) == L * 48).all(), bb.sum(axis=0)
    # rows are independent: permuting slots permutes allocations
    perm = [2, 0, 3, 1]
    bp = np.asarray(sparsity.allocate_budgets_batched(
        sp[:, perm], capacity=128, nominal=48, min_budget=8, sink_len=4,
        recent_len=9))
    np.testing.assert_array_equal(bp, bb[:, perm])


# --------------------------------------------------------------------------
# Hoyer sparsity edges (the n = 2.0 clamp and degenerate inputs)
# --------------------------------------------------------------------------

def test_hoyer_single_valid_entry_clamps_to_n2():
    # n_valid = 1 would make sqrt(n) - 1 = 0; the clamp at n = 2.0 instead
    # reports a lone spike as maximally sparse (l1/l2 = 1 exactly).
    a = jnp.zeros(16).at[5].set(3.0)
    where = jnp.zeros(16, bool).at[5].set(True)
    s = float(sparsity.hoyer_sparsity(a, where=where))
    assert s == pytest.approx(1.0)
    # explicit n_valid = 1 and even n_valid = 0 take the same clamp
    s1 = float(sparsity.hoyer_sparsity(a, n_valid=jnp.asarray(1)))
    s0 = float(sparsity.hoyer_sparsity(a, n_valid=jnp.asarray(0)))
    assert s1 == pytest.approx(1.0) and s0 == pytest.approx(1.0)


def test_hoyer_two_valid_entries_match_dense_pair():
    # n_valid = 2 sits exactly at the clamp: masked result == dense 2-vector
    pair = np.asarray([3.0, 1.0], np.float32)
    dense = float(sparsity.hoyer_sparsity(jnp.asarray(pair)))
    a = jnp.zeros(8).at[2].set(3.0).at[6].set(1.0)
    where = jnp.zeros(8, bool).at[2].set(True).at[6].set(True)
    masked = float(sparsity.hoyer_sparsity(a, where=where))
    assert masked == pytest.approx(dense, abs=1e-6)
    assert 0.0 < masked < 1.0


def test_hoyer_all_zero_scores_saturate_not_nan():
    # l2 = 0 hits the _EPS guard: the result must be finite (clips to 1.0,
    # i.e. "nothing attended anywhere" reads as maximally sparse).
    s = float(sparsity.hoyer_sparsity(jnp.zeros(32)))
    assert np.isfinite(s) and s == pytest.approx(1.0)
    rows = sparsity.hoyer_sparsity(jnp.zeros((4, 32)), axis=-1)
    assert np.isfinite(np.asarray(rows)).all()


def test_hoyer_where_fully_false():
    a = jnp.asarray(np.random.default_rng(2).random(24).astype(np.float32))
    s = float(sparsity.hoyer_sparsity(a, where=jnp.zeros(24, bool)))
    assert np.isfinite(s) and 0.0 <= s <= 1.0


def test_hoyer_uniform_vs_onehot_with_mask():
    n = 20
    a_uni = jnp.ones(32) * 0.5
    a_hot = jnp.zeros(32).at[3].set(4.0)
    where = jnp.arange(32) < n
    assert float(sparsity.hoyer_sparsity(a_uni, where=where)) < 1e-5
    assert float(sparsity.hoyer_sparsity(a_hot, where=where)) > 0.999


# --------------------------------------------------------------------------
# Hypothesis-driven conservation (richer sweep when available)
# --------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(0, 1), min_size=1, max_size=16),
           st.integers(32, 512), st.integers(0, 5), st.integers(1, 10))
    def test_budget_conservation_exact_hypothesis(spars, cap, sink, rec):
        nominal = cap // 2
        floor, ceil = _bounds(capacity=cap, min_budget=8, sink_len=sink,
                              recent_len=rec)
        if not (floor <= nominal <= ceil):
            return
        b = _alloc(spars, capacity=cap, nominal=nominal, min_budget=8,
                   sink_len=sink, recent_len=rec)
        assert b.sum() == len(spars) * nominal
        assert (b >= floor).all() and (b <= ceil).all()
except ImportError:                          # pragma: no cover
    pass                                     # seeded sweep above still runs
