import os

# Tests run single-device CPU (the dry-run's 512 fake devices are set ONLY
# inside launch/dryrun.py, which tests exercise via subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
