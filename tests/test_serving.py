"""End-to-end serving behaviour: engine, scheduler, policy grid, memory
accounting, multi-round pruning dynamics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, Scheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, B, S, seed=1):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S),
                                         0, cfg.vocab_size)}


def test_generate_all_policies(setup):
    cfg, model, params = setup
    for kind in ["fullkv", "lethe", "h2o", "streaming", "pyramidkv",
                 "lazyeviction", "gkv"]:
        cap = 96 if kind == "fullkv" else 24
        pol = make_policy(kind, capacity=cap, sink_len=2, sparse_ratio=4.0,
                          lag_window=4)
        eng = Engine(model, params, pol)
        res = eng.generate(_prompt(cfg, 2, 16), 12)
        assert res.tokens.shape == (2, 12)
        assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()


def test_lethe_bounds_cache_memory(setup):
    """The central paper claim in system form: Lethe's cache stays bounded
    during long decode while FullKV grows linearly."""
    cfg, model, params = setup
    full = Engine(model, params, make_policy("fullkv", capacity=128))
    lethe = Engine(model, params,
                   make_policy("lethe", capacity=32, sink_len=2,
                               sparse_ratio=4.0, target_fill=0.5))
    r_full = full.generate(_prompt(cfg, 2, 16), 40, trace_live=True)
    r_lethe = lethe.generate(_prompt(cfg, 2, 16), 40, trace_live=True)
    assert r_lethe.cache_bytes < r_full.cache_bytes
    # FullKV live tokens grow without bound; Lethe plateaus below capacity
    assert r_full.live_token_trace[-1] > r_lethe.live_token_trace[-1]
    max_slots = 32 * cfg.n_layers * 2  # capacity × layers × batch
    assert max(r_lethe.live_token_trace) <= max_slots


def test_multi_round_pruning_happens(setup):
    """Occupancy must repeatedly rise and fall (multi-round pruning), not
    prune once and stop."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=3.0,
                      target_fill=0.5)
    eng = Engine(model, params, pol)
    res = eng.generate(_prompt(cfg, 1, 12), 60, trace_live=True)
    trace = np.asarray(res.live_token_trace)
    drops = int(np.sum(np.diff(trace) < 0))
    assert drops >= 2, f"expected multiple pruning rounds, trace={trace}"


def test_generate_scan_matches_python_loop_greedy(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=32, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    r1 = eng.generate(_prompt(cfg, 2, 16), 8)
    r2 = eng.generate_scan(_prompt(cfg, 2, 16), 8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_scheduler_drains_queue(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=32, sink_len=2)
    eng = Engine(model, params, pol)
    sched = Scheduler(eng, batch_slots=3)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8 + i % 4),
                    max_new_tokens=6) for i in range(7)]
    sched.submit(reqs)
    done = sched.run()
    assert [c.uid for c in done] == list(range(7))
    assert all(c.tokens.shape == (6,) for c in done)


def test_fullkv_overflow_protection(setup):
    """FullKV at capacity must not corrupt state (clamp-write, no crash)."""
    cfg, model, params = setup
    pol = make_policy("fullkv", capacity=20)
    eng = Engine(model, params, pol)
    res = eng.generate(_prompt(cfg, 1, 16), 10)  # 16 + 10 > 20
    assert np.isfinite(res.tokens).all()
