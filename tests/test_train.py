"""Training substrate: loss decreases on structured synthetic data;
optimizer/checkpoint roundtrips."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import pipeline
from repro.launch import steps
from repro.models.api import build_model
from repro.optim import adamw


def test_loss_decreases_dense():
    cfg = dataclasses.replace(
        get_arch("qwen2.5-32b").reduced(), vocab_size=128, d_ff=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    train_step = jax.jit(steps.make_train_step(model, opt_cfg))
    opt_state = adamw.init(params)
    data = pipeline.lm_stream(pipeline.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch_size=8, seed=0))
    losses = []
    for i, batch in zip(range(40), data):
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::8]
    assert np.isfinite(losses).all()


def test_loss_decreases_reasoning_task():
    rcfg = pipeline.ReasoningConfig(n_values=32, n_steps=6, batch_size=8)
    cfg = dataclasses.replace(get_arch("granite-20b").reduced(),
                              vocab_size=rcfg.vocab_size)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    train_step = jax.jit(steps.make_train_step(model, opt_cfg))
    opt_state = adamw.init(params)
    losses = []
    for i in range(50):
        batch = pipeline.reasoning_batch(rcfg, i)
        batch = {"tokens": batch["tokens"],
                 "loss_weights": batch["loss_weights"]}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (losses[0], losses[-1])


def test_grad_clipping_and_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            grad_clip=1e-9)
    p = {"w": jnp.ones((4, 4))}
    st = adamw.init(p)
    g = {"w": jnp.full((4, 4), 1e6)}
    new_p, st2, m = adamw.update(g, st, p, cfg)
    # clip makes the step tiny despite the huge gradient and lr
    assert float(jnp.abs(new_p["w"] - p["w"]).max()) < 1.0
    assert float(m["grad_norm"]) > 1e5
    # warmup: lr at step 1 is lr/10
    np.testing.assert_allclose(float(adamw.schedule(jnp.int32(1), cfg)), 0.1)


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt
    cfg = get_arch("mixtral-8x7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "ck")
    ckpt.save(path, params, step=7)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = ckpt.restore(path, zeros)
    ok = jax.tree.map(lambda a, b: bool((a == b).all()), params, restored)
    assert all(jax.tree.leaves(ok))
    assert ckpt.latest_step(path) == 7


def test_data_pipeline_determinism():
    from repro.data import pipeline as pl
    c = pl.DataConfig(vocab_size=64, seq_len=16, batch_size=4, seed=3)
    a = next(pl.lm_stream(c))["tokens"]
    b = next(pl.lm_stream(c))["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    r1 = pl.reasoning_batch(pl.ReasoningConfig(seed=5), 3)
    r2 = pl.reasoning_batch(pl.ReasoningConfig(seed=5), 3)
    np.testing.assert_array_equal(np.asarray(r1["tokens"]),
                                  np.asarray(r2["tokens"]))
    # answers actually follow the chain rule encoded in the tokens
    toks = np.asarray(r1["tokens"])
    assert (toks[:, -1] == np.asarray(r1["answer"])).all()
