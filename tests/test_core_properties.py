"""Property-based tests (hypothesis) on the Lethe core invariants.

Skipped cleanly (instead of aborting collection of the whole suite) when
``hypothesis`` is not installed; ``pip install -r requirements-dev.txt``
provides it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import cache as cache_lib
from repro.core import pruning, sparsity
from repro.core.policy import make_policy

SET = settings(max_examples=40, deadline=None)


# --------------------------------------------------------------------------
# Hoyer sparsity (Eq. 1)
# --------------------------------------------------------------------------

@SET
@given(hnp.arrays(np.float32, st.integers(2, 64),
                  elements=st.floats(0, 100, width=32)))
def test_hoyer_bounds(a):
    s = float(sparsity.hoyer_sparsity(jnp.asarray(a)))
    assert 0.0 <= s <= 1.0


@SET
@given(hnp.arrays(np.float32, st.integers(2, 64),
                  elements=st.floats(0.015625, 100, width=32)),
       st.floats(0.125, 50))
def test_hoyer_scale_invariance(a, c):
    s1 = float(sparsity.hoyer_sparsity(jnp.asarray(a)))
    s2 = float(sparsity.hoyer_sparsity(jnp.asarray(a * np.float32(c))))
    assert abs(s1 - s2) < 1e-3


def test_hoyer_extremes():
    onehot = jnp.zeros(32).at[3].set(5.0)
    uniform = jnp.full(32, 0.25)
    assert float(sparsity.hoyer_sparsity(onehot)) > 0.999
    assert float(sparsity.hoyer_sparsity(uniform)) < 1e-6


# --------------------------------------------------------------------------
# Budget allocator
# --------------------------------------------------------------------------

@SET
@given(hnp.arrays(np.float32, st.integers(2, 32),
                  elements=st.floats(0, 1, width=32)))
def test_budget_allocation_conserves_and_bounds(spars):
    cap, nominal, minb = 256, 128, 16
    b = sparsity.allocate_budgets(jnp.asarray(spars), capacity=cap,
                                  nominal=nominal, min_budget=minb,
                                  sink_len=4, recent_len=8)
    b = np.asarray(b)
    assert (b >= min(minb, 4 + 8 + 1)).all()
    assert (b <= cap).all()
    # total within 20% of the uniform-nominal total (clipping slack aside)
    assert abs(int(b.sum()) - len(spars) * nominal) <= 0.2 * len(
        spars) * nominal + cap


def test_budget_allocator_gives_denser_layers_more():
    spars = jnp.asarray([0.1, 0.9, 0.5])
    b = np.asarray(sparsity.allocate_budgets(
        spars, capacity=512, nominal=128, min_budget=8, sink_len=2,
        recent_len=4))
    assert b[0] > b[2] > b[1]


# --------------------------------------------------------------------------
# Algorithm 1 breakpoint
# --------------------------------------------------------------------------

@SET
@given(st.integers(8, 64), st.floats(1.5, 100.0), st.integers(2, 8))
def test_breakpoint_consistency(n, tau, d_seg):
    rng = np.random.default_rng(n)
    scores = np.sort(rng.exponential(1.0, n).astype(np.float32))[::-1].copy()
    full = np.full(128, -np.inf, np.float32)
    full[:n] = scores
    bp, salient = pruning.algorithm1_breakpoint(
        jnp.asarray(full), jnp.int32(n), n_segments=d_seg, tau=tau)
    bp = int(bp)
    sal = np.asarray(salient)
    if bp >= 0:
        # salient = top-bp by score; ratio at the breakpoint must exceed τ
        assert sal.sum() == bp
        assert scores[0] / max(scores[min(bp, n - 1)], 1e-9) > tau or \
            scores[min(bp, n - 1)] <= 0
    else:
        assert sal.sum() == 0
        # no cut-point ratio may exceed τ
        cuts = [max(1, (n * d) // d_seg) for d in range(1, d_seg)]
        for c in cuts:
            assert scores[0] / max(scores[c], 1e-9) <= tau + 1e-3


def test_monotone_tau_keeps_more():
    """Larger sparse_ratio (τ) must never retain fewer tokens (Table 6)."""
    n = 64
    rng = np.random.default_rng(0)
    scores = np.sort(rng.exponential(1.0, n).astype(np.float32))[::-1].copy()
    full = jnp.asarray(np.pad(scores, (0, 64), constant_values=-np.inf))
    kept = []
    for tau in [1.5, 3.0, 10.0, 100.0]:
        bp, salient = pruning.algorithm1_breakpoint(
            full, jnp.int32(n), n_segments=8, tau=tau)
        kept.append(int(np.asarray(salient).sum()) if int(bp) >= 0 else n)
    assert kept == sorted(kept)


# --------------------------------------------------------------------------
# Compaction / pruning invariants
# --------------------------------------------------------------------------

def _mk_layer(B=2, Hkv=2, C=64, Dh=8, n_valid=40, seed=0):
    pol = make_policy("lethe", capacity=C, sink_len=2)
    c = cache_lib.init_cache(n_layers=1, batch=B, n_kv_heads=Hkv, capacity=C,
                             d_head=Dh, policy=pol, dtype=jnp.float32)
    lay = c.layer(0)
    key = jax.random.PRNGKey(seed)
    for t in range(n_valid):
        kn = jax.random.normal(jax.random.fold_in(key, t), (B, Hkv, Dh))
        lay = cache_lib.append_token(lay, kn, kn, t, 1.0)
    return lay, pol


@SET
@given(st.integers(10, 60), st.floats(1.2, 20.0), st.integers(1, 4))
def test_prune_invariants(n_valid, tau, seed):
    lay, _ = _mk_layer(n_valid=n_valid, seed=seed)
    pol = make_policy("lethe", capacity=64, sink_len=2, sparse_ratio=tau)
    rng = np.random.default_rng(seed)
    sc = jnp.asarray(rng.exponential(1.0, (2, 64)).astype(np.float32))
    sc = jnp.where(lay.pos >= 0, sc, 0.0)
    lay = cache_lib.KVCache(lay.k, lay.v, lay.pos, sc, lay.length,
                            lay.budget, lay.evict_at, lay.sparsity)
    cur = jnp.int32(n_valid - 1)
    out = pruning.prune_layer(lay, cur, policy=pol, force=True)
    pos = np.asarray(out.pos)
    length = np.asarray(out.length)
    for b in range(pos.shape[0]):
        live = pos[b][pos[b] >= 0]
        # occupancy bookkeeping
        assert len(live) == length[b]
        # packed front, increasing positions
        assert (pos[b][:length[b]] >= 0).all()
        assert (pos[b][length[b]:] == -1).all()
        assert (np.diff(live) > 0).all()
        # sinks always kept
        for s in range(min(pol.sink_len, n_valid)):
            assert s in live
        # most recent token always kept
        assert (n_valid - 1) in live
        # never exceeds the capacity backstop
        assert length[b] <= 64 * 15 // 16


@SET
@given(st.sampled_from(["h2o", "streaming", "pyramidkv", "lethe",
                        "lazyeviction", "gkv"]))
def test_all_policies_respect_protections(kind):
    lay, _ = _mk_layer(n_valid=50, seed=7)
    pol = make_policy(kind, capacity=64, sink_len=3, sparse_ratio=2.0,
                      target_fill=0.4)
    rng = np.random.default_rng(1)
    sc = jnp.asarray(rng.exponential(1.0, (2, 64)).astype(np.float32))
    sc = jnp.where(lay.pos >= 0, sc, 0.0)
    lay = cache_lib.KVCache(lay.k, lay.v, lay.pos, sc, lay.length,
                            lay.budget, lay.evict_at, lay.sparsity)
    out = pruning.prune_layer(lay, jnp.int32(49), policy=pol, force=True)
    pos = np.asarray(out.pos)
    for b in range(2):
        live = set(pos[b][pos[b] >= 0].tolist())
        assert {0, 1, 2} <= live          # sinks
        assert 49 in live                 # most recent


def test_compaction_preserves_kv_alignment():
    """After compaction, slot i's K/V must be the K/V originally written for
    slot i's position."""
    lay, pol = _mk_layer(B=1, Hkv=1, C=32, Dh=4, n_valid=20, seed=3)
    # tag each position: k[...] = pos value
    k_tagged = jnp.broadcast_to(
        jnp.arange(32, dtype=jnp.float32)[None, None, :, None],
        lay.k.shape)
    k_tagged = jnp.where((lay.pos >= 0)[:, None, :, None], k_tagged, -1.0)
    # overwrite tags with the position itself
    tag = jnp.where(lay.pos >= 0, lay.pos.astype(jnp.float32), -1.0)
    k_tagged = jnp.broadcast_to(tag[:, None, :, None], lay.k.shape)
    lay = cache_lib.KVCache(k_tagged, k_tagged, lay.pos, lay.score,
                            lay.length, lay.budget, lay.evict_at,
                            lay.sparsity)
    keep = (lay.pos % 3 == 0) & (lay.pos >= 0)
    out = cache_lib.compact(lay, keep)
    pos = np.asarray(out.pos[0])
    kv = np.asarray(out.k[0, 0, :, 0])
    for i, p in enumerate(pos):
        if p >= 0:
            assert kv[i] == p, (i, p, kv[i])


# --------------------------------------------------------------------------
# RASR (Eq. 5)
# --------------------------------------------------------------------------

@SET
@given(st.floats(0.5, 1.0), st.integers(1, 20))
def test_rasr_ema_math(gamma, steps):
    from repro.core import rasr
    lay, _ = _mk_layer(B=1, n_valid=10, seed=0)
    expected = np.asarray(lay.score[0]).copy()
    probsum = np.zeros((1, 64), np.float32)
    probsum[0, :10] = 0.5
    for _ in range(steps):
        lay = rasr.update_scores(lay, jnp.asarray(probsum), gamma)
        expected = gamma * expected + probsum[0]
    expected[10:] = 0.0  # invalid slots zeroed
    np.testing.assert_allclose(np.asarray(lay.score[0]), expected, rtol=1e-4)
