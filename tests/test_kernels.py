"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle across
shape/dtype sweeps, as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import (GLOBAL_WINDOW,
                                            decode_attention_pallas,
                                            live_lengths)
from repro.kernels.flash_prefill import flash_prefill_pallas


def _fused(q, k, v, pos, cur, *, scale, window=None, softcap=None,
           score=None, gamma=0.0, block_c=512):
    """Call the fused kernel in interpret mode with wrapper-derived lengths."""
    if score is None:
        score = jnp.zeros(pos.shape, jnp.float32)
    win = GLOBAL_WINDOW if window is None else window
    return decode_attention_pallas(
        q, k, v, pos, score, live_lengths(pos), cur, jnp.int32(win),
        scale=scale, softcap=softcap, gamma=gamma, block_c=block_c,
        interpret=True)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


DECODE_SHAPES = [
    # B, Hq, Hkv, C, Dh, block_c
    (1, 4, 4, 64, 32, 16),       # MHA
    (2, 8, 2, 96, 32, 32),       # GQA, C not multiple of block
    (2, 6, 1, 128, 64, 64),      # MQA
    (1, 16, 8, 48, 16, 16),      # small C
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", DECODE_SHAPES)
def test_decode_attention_matches_ref(shape, dtype):
    B, Hq, Hkv, C, Dh, bc = shape
    ks = jax.random.split(jax.random.PRNGKey(42), 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh), dtype)
    pos = jnp.where(jax.random.uniform(ks[3], (B, C)) < 0.75,
                    jnp.arange(C)[None], -1).astype(jnp.int32)
    pos = pos.at[:, 0].set(0)  # ensure at least one valid slot
    cur = jnp.int32(C + 3)

    o_ref, ps_ref = ref.decode_attention_ref(q, k, v, pos, cur,
                                             scale=Dh ** -0.5)
    o_pl, ps_pl, _, _ = _fused(q, k, v, pos, cur, scale=Dh ** -0.5,
                               block_c=bc)
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(ps_pl), np.asarray(ps_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("window,softcap", [(None, None), (40, None),
                                            (None, 30.0), (24, 50.0)])
def test_decode_attention_masking_variants(window, softcap):
    B, Hq, Hkv, C, Dh = 2, 8, 2, 80, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)
    cur = jnp.int32(C - 1)
    o_ref, ps_ref = ref.decode_attention_ref(
        q, k, v, pos, cur, window=window, softcap=softcap, scale=Dh ** -0.5)
    o_pl, ps_pl, _, _ = _fused(q, k, v, pos, cur, scale=Dh ** -0.5,
                               window=window, softcap=softcap, block_c=32)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(ps_pl), np.asarray(ps_ref),
                               rtol=1e-4, atol=1e-5)


def test_decode_probsum_is_valid_distribution_mass():
    """Σ_c probsum[b, c] must equal Hq (each head's row sums to 1)."""
    B, Hq, Hkv, C, Dh = 2, 8, 4, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)
    _, ps, _, _ = _fused(q, k, v, pos, jnp.int32(C), scale=Dh ** -0.5,
                         block_c=16)
    np.testing.assert_allclose(np.asarray(jnp.sum(ps, -1)),
                               np.full((B,), Hq, np.float32), rtol=1e-5)


PREFILL_SHAPES = [
    # B, Hq, Hkv, S, T, Dh, bq, bk
    (1, 4, 4, 64, 64, 32, 32, 32),
    (2, 8, 2, 80, 80, 32, 16, 32),    # ragged block boundaries
    (1, 6, 1, 128, 128, 64, 64, 64),  # MQA
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", PREFILL_SHAPES)
def test_flash_prefill_matches_ref(shape, dtype):
    B, Hq, Hkv, S, T, Dh, bq, bk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, Dh), dtype)
    o_ref, lse_ref = ref.prefill_attention_ref(q, k, v, causal=True,
                                               scale=Dh ** -0.5)
    o_pl, lse_pl = flash_prefill_pallas(q, k, v, scale=Dh ** -0.5,
                                        block_q=bq, block_k=bk,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(lse_pl), np.asarray(lse_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("window,softcap", [(24, None), (None, 50.0),
                                            (16, 30.0)])
def test_flash_prefill_window_softcap(window, softcap):
    B, Hq, Hkv, S, Dh = 2, 4, 2, 96, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    o_ref, _ = ref.prefill_attention_ref(q, k, v, causal=True, window=window,
                                         softcap=softcap, scale=Dh ** -0.5)
    o_pl, _ = flash_prefill_pallas(q, k, v, scale=Dh ** -0.5, window=window,
                                   softcap=softcap, block_q=32, block_k=32,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_prefill_q_offset_chunked():
    """Chunked prefill: two q-chunks with offsets == one full pass."""
    B, Hq, Hkv, S, Dh = 1, 4, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    o_full, _ = ref.prefill_attention_ref(q, k, v, causal=True,
                                          scale=Dh ** -0.5)
    h = S // 2
    o1, _ = flash_prefill_pallas(q[:, :, :h], k, v, scale=Dh ** -0.5,
                                 block_q=16, block_k=16, q_offset=0,
                                 interpret=True)
    o2, _ = flash_prefill_pallas(q[:, :, h:], k, v, scale=Dh ** -0.5,
                                 block_q=16, block_k=16, q_offset=h,
                                 interpret=True)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([o1, o2], axis=2)), np.asarray(o_full),
        rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,plan,bq,bk", [
    (40, (16, 16, 8), 16, 16),      # final partial q_offset chunk
    (72, (32, 32, 8), 16, 32),      # S % block_k != 0 (72 % 32)
    (23, (8, 8, 4, 2, 1), 8, 16),   # nothing aligned: pow2 cascade
])
def test_flash_prefill_partial_chunk_cascade(S, plan, bq, bk):
    """Chunked prefill's kernel contract: a cascade of q_offset chunks —
    including a final chunk smaller than block_q, and sequence lengths not
    a multiple of either block size — reproduces the full pass, per chunk
    against the oracle and concatenated against the full oracle."""
    assert sum(plan) == S
    B, Hq, Hkv, Dh = 2, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    o_full, _ = ref.prefill_attention_ref(q, k, v, causal=True,
                                          scale=Dh ** -0.5)
    outs, done = [], 0
    for n in plan:
        qc = q[:, :, done:done + n]
        kc = k[:, :, :done + n]       # keys accumulated so far
        vc = v[:, :, :done + n]
        o_pl, _ = flash_prefill_pallas(qc, kc, vc, scale=Dh ** -0.5,
                                       block_q=bq, block_k=bk,
                                       q_offset=done, interpret=True)
        o_ref, _ = ref.prefill_attention_ref(qc, kc, vc, causal=True,
                                             scale=Dh ** -0.5,
                                             q_offset=done)
        np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"chunk at {done}")
        outs.append(o_pl)
        done += n
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, axis=2)), np.asarray(o_full),
        rtol=2e-5, atol=2e-5)


def test_flash_prefill_q_offset_with_window():
    """Sliding window + q_offset: a middle chunk whose window excludes part
    of the key prefix (the RG-LRU local-attention chunked path)."""
    B, Hq, Hkv, S, Dh, W = 1, 4, 2, 64, 32, 20
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    o_full, _ = ref.prefill_attention_ref(q, k, v, causal=True, window=W,
                                          scale=Dh ** -0.5)
    outs, done = [], 0
    for n in (32, 16, 16):
        o_pl, _ = flash_prefill_pallas(
            q[:, :, done:done + n], k[:, :, :done + n], v[:, :, :done + n],
            scale=Dh ** -0.5, window=W, block_q=16, block_k=16,
            q_offset=done, interpret=True)
        outs.append(o_pl)
        done += n
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, axis=2)), np.asarray(o_full),
        rtol=2e-5, atol=2e-5)


def test_chunk_attention_contiguous_matches_prefill_ref():
    """The slotted chunk-attention oracle on a contiguous buffer (invalid
    tail masked by k_pos = -1) is BIT-identical to the dense q_offset
    oracle: masked sentinel scores underflow to exact zeros."""
    B, Hq, Hkv, S, Cbuf, Dh = 2, 4, 2, 12, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    kp = jnp.zeros((B, Hkv, Cbuf, Dh)).at[:, :, :S].set(k)
    vp = jnp.zeros((B, Hkv, Cbuf, Dh)).at[:, :, :S].set(v)
    pos = jnp.where(jnp.arange(Cbuf) < S, jnp.arange(Cbuf), -1)
    pos = jnp.broadcast_to(pos, (B, Cbuf)).astype(jnp.int32)
    done = 8
    o_ref, _ = ref.prefill_attention_ref(
        q[:, :, done:], k, v, causal=True, scale=Dh ** -0.5, q_offset=done)
    o_ch = ref.chunk_attention_ref(q[:, :, done:], kp, vp, pos, done,
                                   scale=Dh ** -0.5)
    np.testing.assert_array_equal(np.asarray(o_ch), np.asarray(o_ref))


def test_chunk_attention_single_query_matches_decode_ref():
    """Cross-oracle check: a one-token chunk over a scattered (compressed)
    slot layout must agree with the decode-attention oracle."""
    B, Hq, Hkv, C, Dh = 2, 8, 2, 48, 32
    ks = jax.random.split(jax.random.PRNGKey(19), 4)
    q = jax.random.normal(ks[0], (B, Hq, 1, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    # scattered positions with holes, invalid tail
    pos = jnp.where(jax.random.uniform(ks[3], (B, C)) < 0.6,
                    jnp.arange(C) * 2, -1).astype(jnp.int32)
    pos = pos.at[:, 0].set(0)
    cur = jnp.int32(2 * C)
    o_dec, _ = ref.decode_attention_ref(q[:, :, 0], k, v, pos, cur,
                                        scale=Dh ** -0.5)
    o_ch = ref.chunk_attention_ref(q, k, v, pos, cur, scale=Dh ** -0.5)
    np.testing.assert_allclose(np.asarray(o_ch[:, :, 0]), np.asarray(o_dec),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("window", [None, 16])
def test_chunk_attention_window_on_scattered_slots(window):
    """Slotted chunk attention with a sliding window: windowed-out and
    invalid slots get no probability mass (checked via a brute-force
    masked softmax)."""
    B, Hq, Hkv, n, C, Dh = 1, 2, 1, 4, 24, 16
    ks = jax.random.split(jax.random.PRNGKey(23), 4)
    q = jax.random.normal(ks[0], (B, Hq, n, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.where(jax.random.uniform(ks[3], (B, C)) < 0.5,
                    jnp.arange(C), -1).astype(jnp.int32)
    pos = pos.at[:, 0].set(0)
    q_start = jnp.int32(C)
    out = ref.chunk_attention_ref(q, k, v, pos, q_start, window=window,
                                  scale=Dh ** -0.5)
    # brute force
    qf = q.astype(jnp.float32).reshape(B, Hkv, Hq // Hkv, n, Dh)
    s = jnp.einsum("bhgsd,bhcd->bhgsc", qf, k) * Dh ** -0.5
    q_pos = jnp.arange(n) + q_start
    mask = (pos[:, None, :] >= 0) & (pos[:, None, :] <= q_pos[None, :, None])
    if window is not None:
        mask &= pos[:, None, :] >= (q_pos[None, :, None] - window + 1)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhgsc,bhcd->bhgsd", p, v).reshape(B, Hq, n, Dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_obs_colsums_match_full_probs():
    B, Hq, Hkv, S, Dh, W = 1, 4, 2, 48, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    colsums, probs = ref.obs_colsums_ref(q[:, :, -W:], k, win_start=S - W,
                                         scale=Dh ** -0.5)
    assert probs.shape == (B, Hq, W, S)
    # each prob row is a distribution over the causal prefix
    np.testing.assert_allclose(np.asarray(jnp.sum(probs, -1)), 1.0,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.sum(colsums, -1)),
                               Hq * W, rtol=1e-5)
