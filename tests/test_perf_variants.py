"""The §Perf optimization switches must be *semantics-preserving*: every
variant changes sharding/layout only, so outputs must match the baseline
bit-for-bit (or to float tolerance) on a single device."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_lib
from repro.core.policy import make_policy
from repro.kernels import ref


def _mk_layer(seed=0, B=2, Hkv=2, C=32, Dh=8, n=20):
    pol = make_policy("lethe", capacity=C)
    c = cache_lib.init_cache(n_layers=1, batch=B, n_kv_heads=Hkv, capacity=C,
                             d_head=Dh, policy=pol, dtype=jnp.float32)
    lay = c.layer(0)
    key = jax.random.PRNGKey(seed)
    steps = []
    for t in range(n):
        kn = jax.random.normal(jax.random.fold_in(key, t), (B, Hkv, Dh))
        steps.append(kn)
    return lay, steps


def test_onehot_append_equals_scatter_append(monkeypatch):
    lay_a, steps = _mk_layer()
    lay_b = jax.tree.map(jnp.copy, lay_a)
    monkeypatch.setenv("REPRO_ONEHOT_APPEND", "1")
    for t, kn in enumerate(steps):
        lay_a = cache_lib.append_token(lay_a, kn, kn, t, 1.0)
    monkeypatch.setenv("REPRO_ONEHOT_APPEND", "0")
    for t, kn in enumerate(steps):
        lay_b = cache_lib.append_token(lay_b, kn, kn, t, 1.0)
    for name in ("k", "v", "pos", "score", "length"):
        np.testing.assert_array_equal(
            np.asarray(getattr(lay_a, name)), np.asarray(getattr(lay_b, name)),
            err_msg=name)


def test_onehot_append_at_capacity_clamps_like_scatter(monkeypatch):
    lay_a, _ = _mk_layer(C=8, n=0)
    lay_b = jax.tree.map(jnp.copy, lay_a)
    key = jax.random.PRNGKey(1)
    for t in range(12):  # overflow: 12 appends into 8 slots
        kn = jax.random.normal(jax.random.fold_in(key, t), (2, 2, 8))
        monkeypatch.setenv("REPRO_ONEHOT_APPEND", "1")
        lay_a = cache_lib.append_token(lay_a, kn, kn, t, 1.0)
        monkeypatch.setenv("REPRO_ONEHOT_APPEND", "0")
        lay_b = cache_lib.append_token(lay_b, kn, kn, t, 1.0)
    np.testing.assert_array_equal(np.asarray(lay_a.pos), np.asarray(lay_b.pos))
    np.testing.assert_array_equal(np.asarray(lay_a.k), np.asarray(lay_b.k))


def test_moe_dispatch_modes_numerically_equal(monkeypatch):
    """Sharding constraints are no-ops on one device — all modes equal."""
    import dataclasses
    from repro.configs import get_arch
    from repro.models import moe
    cfg = get_arch("mixtral-8x7b").reduced()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 7, cfg.d_model))
    outs = []
    for mode in ("0", "1", "2"):
        monkeypatch.setenv("REPRO_MOE_SHARD_DISPATCH", mode)
        out, aux = moe.apply_moe(x, p, cfg)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6)


def test_prefill_seq_shard_hint_is_noop_single_device(monkeypatch):
    from repro.configs import get_arch
    from repro.models import transformer
    from repro.core.policy import make_policy as mp
    cfg = get_arch("qwen2.5-32b").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    pol = mp("lethe", capacity=16)
    monkeypatch.setenv("REPRO_PREFILL_SEQ_SHARD", "0")
    jax.clear_caches()
    l0, _ = transformer.prefill(params, toks, cfg, pol)
    monkeypatch.setenv("REPRO_PREFILL_SEQ_SHARD", "1")
    jax.clear_caches()
    l1, _ = transformer.prefill(params, toks, cfg, pol)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-6)


def test_chunked_prefill_ref_matches_full():
    B, Hq, Hkv, S, Dh = 1, 4, 2, 72, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    full, _ = ref.prefill_attention_ref(q, k, v, causal=True,
                                        scale=Dh ** -0.5)
    chunked = ref.prefill_attention_chunked_ref(q, k, v, chunk=16,
                                                causal=True, scale=Dh ** -0.5)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_collective_parser_on_synthetic_hlo():
    from repro.roofline import analysis
    hlo = """
  %all-gather.3 = bf16[4,128]{1,0} all-gather(%x), replica_groups={}
  %ar = (f32[8,8]{1,0}, f32[2]{0}) all-reduce-start(%y, %z), channel_id=1
  %ar.done = f32[8,8]{1,0} all-reduce-done(%ar)
  %cp = u32[16]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_a_coll = f32[2,2]{1,0} add(%a, %b)
"""
    out = analysis.collective_bytes(hlo)
    assert out["all-gather"] == 4 * 128 * 2
    assert out["all-reduce"] == 8 * 8 * 4 + 2 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == (4 * 128 * 2) + (8 * 8 * 4 + 2 * 4) + 16 * 4


def test_roofline_terms_math():
    from repro.roofline import analysis
    t = analysis.roofline(197e12, 819e9, 50e9, 256, model_flops=197e12 * 256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert abs(t.flops_ratio - 1.0) < 1e-9
