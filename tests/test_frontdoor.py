"""SLO front-door battery.

Covers the serving-robustness guarantees DESIGN.md §Robustness promises:
  * differential — the front door reproduces solo per-request greedy tokens
    exactly when the ladder is transparent, and STILL does after forced
    preemption-to-host + resume, for every policy, bf16 and int8;
  * preemption snapshots round-trip bit-exactly and never touch neighbors;
  * priorities (outranking arrivals preempt residents), deadlines and
    decode timeouts (injectable clock), typed terminal reasons;
  * the degradation ladder: compressed admission, live int8 migration,
    load shedding, rejection — each rung observable and typed;
  * the asyncio shell streams exactly the tokens the core produced.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, FrontDoor,
                                     FrontDoorCore, ServeRequest)

INF = float("inf")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, spec, seed=0, priorities=None, **kw):
    """spec: list of (prompt_len, max_new) -> uid-ordered ServeRequests."""
    rng = np.random.default_rng(seed)
    prios = priorities or [0] * len(spec)
    return [ServeRequest(uid=i,
                         prompt=rng.integers(0, cfg.vocab_size,
                                             size=s).astype(np.int32),
                         max_new_tokens=n, priority=p, **kw)
            for i, ((s, n), p) in enumerate(zip(spec, prios))]


def _solo(engine, req, eos_id=None):
    res = engine.generate({"tokens": jnp.asarray(req.prompt)[None, :]},
                          req.max_new_tokens, eos_id=eos_id)
    return np.asarray(res.tokens[0, :res.gen_lens[0]])


def _transparent(**kw):
    """Admission config with every ladder rung out of reach — the front
    door must then be token-equivalent to the plain scheduler."""
    base = dict(compress_at=INF, shed_at=INF, reject_at=INF)
    base.update(kw)
    return AdmissionConfig(**base)


class FakeClock:
    """Injectable wall clock: tests advance time explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# --------------------------------------------------------------------------
# Differential: transparent front door == per-request greedy
# --------------------------------------------------------------------------

def test_frontdoor_matches_solo(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    reqs = _reqs(cfg, [(8, 3), (12, 9), (8, 14), (12, 6), (8, 7)], seed=0)
    solo = {r.uid: _solo(eng, r) for r in reqs}

    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent())
    core.submit(reqs)
    done = core.run()
    assert [c.uid for c in done] == [r.uid for r in reqs]
    for c in done:
        np.testing.assert_array_equal(np.asarray(c.tokens), solo[c.uid],
                                      err_msg=f"uid {c.uid}")
    s = core.run_summary()
    # a healthy under-capacity run exercises zero robustness machinery
    assert s["shed"] == s["preempted"] == s["timeout"] == 0
    assert s["failed"] == s["rejected"] == 0
    assert s["completed"] == len(reqs)


# --------------------------------------------------------------------------
# Preemption-to-host: bit-exact resume, all policies, bf16 and int8
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming",
                                  "lazyeviction", "gkv"])
@pytest.mark.parametrize("kv_format", ["bf16", "int8"])
def test_preempt_resume_differential(setup, kind, kv_format):
    """Forcing preemption at segment boundaries must not change a single
    token of any request: the host snapshot (KV + scales + scores + budget
    + cursor) IS the complete per-request state."""
    cfg, model, params = setup
    pol = make_policy(kind, capacity=24, sink_len=2, sparse_ratio=4.0,
                      target_fill=0.5, kv_format=kv_format)
    eng = Engine(model, params, pol)
    reqs = _reqs(cfg, [(8, 12), (12, 10), (8, 14), (12, 11)], seed=3)
    solo = {r.uid: _solo(eng, r) for r in reqs}

    core = FrontDoorCore(eng, batch_slots=2, segment_len=3,
                         admission=_transparent())
    core.submit(reqs)
    core.step()                       # residents have decoded one segment
    forced = 0
    for victim in (0, 1):
        if core.slots[victim] is not None:
            core.preempt_slot(victim)
            forced += 1
    assert forced >= 1
    core.step()                       # someone resumed, decode continues
    if core.slots[0] is not None:     # preempt a resumed request again
        core.preempt_slot(0)
        forced += 1
    done = core.run()

    assert [c.uid for c in done] == [r.uid for r in reqs]
    for c in done:
        np.testing.assert_array_equal(
            np.asarray(c.tokens), solo[c.uid],
            err_msg=f"uid {c.uid} ({kind}/{kv_format})")
    assert sum(c.preemptions for c in done) == forced
    assert core.run_summary()["preempted"] == forced


def _rows_without(state, skip_slot):
    rows = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        rows[jax.tree_util.keystr(path)] = np.delete(
            np.asarray(leaf), skip_slot, axis=1)
    return rows


def test_preempt_snapshot_roundtrip_and_isolation(setup):
    """Preempt + resume restores the ENTIRE live state bit-exactly, and
    the preempt itself never touches neighbor rows (RASR scores
    included)."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    reqs = _reqs(cfg, [(10, 12), (8, 12), (12, 12)], seed=5)
    core = FrontDoorCore(eng, batch_slots=3, segment_len=4,
                         admission=_transparent())
    core.submit(reqs)
    core.step()
    assert all(s is not None for s in core.slots)

    flat = lambda st: {jax.tree_util.keystr(p): np.asarray(l) for p, l in
                       jax.tree_util.tree_flatten_with_path(st)[0]}
    before_all = flat(core.state)
    before_others = _rows_without(core.state, 1)
    tok1, pos1 = int(core.tok[1]), int(core.pos[1])
    uid1 = core.slots[1].req.uid

    core.preempt_slot(1)
    after_preempt = _rows_without(core.state, 1)
    for name, arr in before_others.items():
        np.testing.assert_array_equal(arr, after_preempt[name],
                                      err_msg=name)
    # the preempted row really was vacated
    assert int(np.asarray(core.state.length)[:, 1].max()) == 0

    # resume puts the snapshot back into the (only) free slot: the whole
    # pool must be bit-identical to the pre-preemption state
    core._admit(0.0)
    assert core.slots[1] is not None and core.slots[1].req.uid == uid1
    assert (int(core.tok[1]), int(core.pos[1])) == (tok1, pos1)
    for name, arr in flat(core.state).items():
        np.testing.assert_array_equal(arr, before_all[name], err_msg=name)


def test_priority_preemption(setup):
    """An outranking arrival preempts the lowest-priority resident; the
    victim resumes later and still finishes healthily."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    lows = _reqs(cfg, [(8, 16), (8, 16)], seed=7)
    (hi,) = _reqs(cfg, [(8, 4)], seed=8, priorities=[5])
    hi = ServeRequest(uid=9, prompt=hi.prompt, max_new_tokens=4, priority=5)
    solo = {r.uid: _solo(eng, r) for r in [*lows, hi]}

    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent(enable_preempt=True))
    core.submit(lows)
    core.step()
    core.submit([hi])
    core.step()
    assert core.n_preemptions == 1
    done = {c.uid: c for c in core.run()}

    assert done[9].finish_reason in ("length", "eos")
    victims = [c for c in done.values() if c.preemptions]
    assert len(victims) == 1 and victims[0].priority == 0
    for uid, c in done.items():
        np.testing.assert_array_equal(np.asarray(c.tokens), solo[uid],
                                      err_msg=f"uid {uid}")


def test_preemption_disabled_never_preempts(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    lows = _reqs(cfg, [(8, 16)], seed=7)
    hi = ServeRequest(uid=5, prompt=lows[0].prompt, max_new_tokens=4,
                      priority=9)
    core = FrontDoorCore(eng, batch_slots=1, segment_len=4,
                         admission=_transparent(enable_preempt=False))
    core.submit(lows)
    core.step()
    core.submit([hi])
    core.step()
    assert core.n_preemptions == 0
    done = core.run()
    assert all(c.finish_reason in ("length", "eos") for c in done)


# --------------------------------------------------------------------------
# Deadlines + decode timeouts (injectable clock)
# --------------------------------------------------------------------------

def test_queued_deadline_times_out(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    clock = FakeClock()
    a, b = _reqs(cfg, [(8, 16), (8, 8)], seed=9)
    b = ServeRequest(uid=1, prompt=b.prompt, max_new_tokens=8,
                     deadline_s=0.5)
    core = FrontDoorCore(eng, batch_slots=1, segment_len=4,
                         admission=_transparent(), clock=clock)
    core.submit([a, b])
    core.step()                        # a admitted, b queued
    clock.t = 1.0                      # b's deadline expires while queued
    core.step()
    done = {c.uid: c for c in core.completed}
    assert done[1].finish_reason == "timeout"
    assert len(done[1].tokens) == 0
    final = {c.uid: c for c in core.run()}
    assert final[0].finish_reason == "length"


def test_decode_timeout_keeps_partial_tokens(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    clock = FakeClock()
    (r,) = _reqs(cfg, [(8, 64)], seed=10)
    r = ServeRequest(uid=0, prompt=r.prompt, max_new_tokens=64,
                     decode_timeout_s=0.5)
    core = FrontDoorCore(eng, batch_slots=1, segment_len=4,
                         admission=_transparent(), clock=clock)
    core.submit([r])
    core.step()                        # first token + one segment
    clock.t = 1.0                      # decode budget blown mid-request
    core.step()
    (c,) = core.completed
    assert c.finish_reason == "timeout"
    assert 0 < len(c.tokens) < 64      # partial output is preserved
    assert core.idle


# --------------------------------------------------------------------------
# The degradation ladder, rung by rung
# --------------------------------------------------------------------------

def test_shed_drops_lowest_priority_only(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=16, sink_len=2)
    eng = Engine(model, params, pol)
    lows = _reqs(cfg, [(8, 8)] * 6, seed=11)
    high = ServeRequest(uid=50, prompt=lows[0].prompt, max_new_tokens=8,
                        priority=3)
    core = FrontDoorCore(
        eng, batch_slots=1, segment_len=4,
        admission=AdmissionConfig(shed_at=1.0, reject_at=INF,
                                  compress_at=INF, enable_shed=True))
    core.submit([*lows, high])
    done = core.run()
    s = core.run_summary()
    assert s["shed"] >= 1
    assert s["completed"] == len(lows) + 1        # every uid terminates
    by_uid = {c.uid: c for c in done}
    assert by_uid[50].finish_reason in ("length", "eos")   # high-pri kept
    for c in done:
        if c.finish_reason == "shed":
            assert c.priority == 0 and len(c.tokens) == 0


def test_reject_rungs(setup):
    """Over-long prompts, a full queue, and reject_at pressure each refuse
    work with the typed ``rejected`` reason — and never crash the pool."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=16, sink_len=2)
    eng = Engine(model, params, pol)

    # prompt > max_admit_factor * capacity
    huge = ServeRequest(uid=0, prompt=np.zeros(64, np.int32),
                        max_new_tokens=4)
    ok = _reqs(cfg, [(8, 4)], seed=12)[0]
    ok = ServeRequest(uid=1, prompt=ok.prompt, max_new_tokens=4)
    core = FrontDoorCore(eng, batch_slots=1, segment_len=4,
                         admission=_transparent())
    core.submit([huge, ok])
    done = {c.uid: c for c in core.run()}
    assert done[0].finish_reason == "rejected"
    assert done[1].finish_reason in ("length", "eos")

    # hard queue cap
    reqs = _reqs(cfg, [(8, 4)] * 4, seed=13)
    core = FrontDoorCore(eng, batch_slots=1, segment_len=4,
                         admission=_transparent(max_queue=1))
    core.submit(reqs)
    core.run()
    s = core.run_summary()
    # the whole burst is ingested before any admission: 1 queued, 3 refused
    assert s["rejected"] == 3
    assert s["completed"] == 4


def test_compress_rung_tightens_admission(setup):
    """Rung 1: under pressure, admissions are force-compressed to the
    ``max_keep`` ceiling — the row goes live under the cap."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    (r,) = _reqs(cfg, [(20, 6)], seed=14)
    core = FrontDoorCore(
        eng, batch_slots=1, segment_len=4,
        admission=AdmissionConfig(compress_at=0.0, compress_keep_frac=0.5,
                                  shed_at=INF, reject_at=INF))
    core.submit([r])
    core._ingest()
    core._admit(core._ladder())        # admission alone, no decode yet
    keep = int(0.5 * pol.capacity)
    assert int(np.asarray(core.state.length).max()) <= keep
    (c,) = core.run()
    assert c.finish_reason in ("length", "eos")
    assert len(c.tokens) >= 1


def test_int8_rung_migrates_live_pool(setup):
    """Rung 2: sustained pressure live-migrates the pool to int8; decode
    continues and completions record the new format."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    reqs = _reqs(cfg, [(8, 10), (10, 10), (8, 10)], seed=15)
    core = FrontDoorCore(
        eng, batch_slots=2, segment_len=4,
        admission=AdmissionConfig(int8_at=0.1, int8_patience=1,
                                  compress_at=INF, shed_at=INF,
                                  reject_at=INF))
    core.submit(reqs)
    done = core.run()
    s = core.run_summary()
    assert s["kv_format"] == "int8"
    assert s["completed"] == len(reqs)
    assert all(c.finish_reason in ("length", "eos") for c in done)
    assert done[-1].kv_format == "int8"


# --------------------------------------------------------------------------
# Asyncio shell
# --------------------------------------------------------------------------

def test_async_submit_and_stream(setup):
    """The shell's streamed tokens are exactly the completion's tokens,
    and plain submits resolve with typed completions."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    r0, r1 = _reqs(cfg, [(8, 8), (10, 5)], seed=16)
    solo = {r.uid: _solo(eng, r) for r in (r0, r1)}

    async def go():
        async with FrontDoor(eng, batch_slots=2, segment_len=4,
                             admission=_transparent()) as fd:
            sub = asyncio.ensure_future(fd.submit(r1))
            streamed = [t async for t in fd.stream(r0)]
            return streamed, fd.completion(r0.uid), await sub

    streamed, c0, c1 = asyncio.run(go())
    np.testing.assert_array_equal(np.asarray(streamed), solo[0])
    np.testing.assert_array_equal(np.asarray(c0.tokens), solo[0])
    np.testing.assert_array_equal(np.asarray(c1.tokens), solo[1])
    assert c0.finish_reason == "length" and c1.finish_reason == "length"


# --------------------------------------------------------------------------
# Shell lifecycle regressions (ISSUE 7 satellites)
# --------------------------------------------------------------------------

def test_shell_maps_bounded_under_many_requests(setup):
    """A long-lived server must not grow per-uid state forever: futures and
    stream queues are dropped as their request completes, and finished
    Completions are kept in a FIFO ring of ``completions_keep``."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    reqs = _reqs(cfg, [(6, 2)] * 12, seed=17)

    async def go():
        async with FrontDoor(eng, batch_slots=2, segment_len=4,
                             completions_keep=4,
                             admission=_transparent()) as fd:
            comps = []
            for r in reqs[:6]:
                comps.append(await fd.submit(r))
            streamed = [t async for t in fd.stream(reqs[6])]
            for r in reqs[7:]:
                comps.append(await fd.submit(r))
            await fd.drain()
            return fd, comps, streamed

    fd, comps, streamed = asyncio.run(go())
    assert not fd._futures and not fd._streams
    assert len(fd._completions) == 4                 # the FIFO ring cap
    # the ring keeps the most recent completions; older ones fell out but
    # the full history stays on the core
    assert fd.completion(reqs[0].uid) is None
    assert fd.completion(reqs[-1].uid) is not None
    assert len(fd.core.completed) == 12
    assert len(streamed) == 2


def test_shell_stop_safe_before_start_and_reentrant(setup):
    """stop() before __aenter__ must not raise (the wake event does not
    exist yet), and a second stop() is a no-op."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24)
    eng = Engine(model, params, pol)

    async def never_started():
        fd = FrontDoor(eng, batch_slots=1, admission=_transparent())
        await fd.stop()                              # no __aenter__ yet
        await fd.stop()                              # re-entrant

    asyncio.run(never_started())

    async def double_stop():
        fd = FrontDoor(eng, batch_slots=1, segment_len=4,
                       admission=_transparent())
        async with fd:
            await fd.submit(_reqs(cfg, [(6, 2)], seed=18)[0])
            await fd.stop()
            await fd.stop()
        await fd.stop()                              # after __aexit__ too

    asyncio.run(double_stop())


def test_shell_drain_covers_late_submissions(setup):
    """drain() must wait for requests submitted AFTER it started — the
    gather re-snapshots until no pending future remains."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    r0, r1 = _reqs(cfg, [(8, 6), (6, 3)], seed=19)

    async def go():
        async with FrontDoor(eng, batch_slots=1, segment_len=4,
                             admission=_transparent()) as fd:
            fut0 = asyncio.ensure_future(fd.submit(r0))

            async def late():
                await asyncio.sleep(0.01)
                return await fd.submit(r1)

            fut1 = asyncio.ensure_future(late())
            await asyncio.sleep(0)                   # let fut0 enqueue
            await fd.drain()
            assert fut0.done()
            assert fut1.done()                       # the late one too
            return await fut0, await fut1

    c0, c1 = asyncio.run(go())
    assert c0.finish_reason == "length" and c1.finish_reason == "length"


def test_ingest_one_cache_stats_sync_per_wave(setup, monkeypatch):
    """Staging N arrivals must cost ONE occupancy read (device sync), not
    N: the live state cannot change between staged arrivals."""
    import repro.serving.frontdoor as fdmod
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent())
    real = fdmod._cache_stats
    calls = []
    monkeypatch.setattr(fdmod, "_cache_stats",
                        lambda state: calls.append(1) or real(state))

    core.submit(_reqs(cfg, [(6, 2)] * 8, seed=20))
    calls.clear()
    core._ingest()
    assert sum(calls) == 1
    assert len(core.queue) == 8
    core._ingest()                                   # nothing staged: free
    assert sum(calls) == 1
