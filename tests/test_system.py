"""End-to-end behaviour of the paper's system: train a tiny reasoning model
in-framework, serve it under Lethe vs FullKV, and verify the paper's core
claims hold as *system invariants* — bounded cache growth, multi-round
adaptive pruning, per-layer budget adaptivity, and output sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.data import pipeline
from repro.launch import steps
from repro.models.api import build_model
from repro.optim import adamw
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def trained():
    rcfg = pipeline.ReasoningConfig(n_values=16, n_steps=8, batch_size=8)
    cfg = dataclasses.replace(get_arch("qwen2.5-32b").reduced(),
                              vocab_size=rcfg.vocab_size)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60)
    train_step = jax.jit(steps.make_train_step(model, opt_cfg))
    opt_state = adamw.init(params)
    first = last = None
    for i in range(60):
        b = pipeline.reasoning_batch(rcfg, i)
        batch = {"tokens": b["tokens"], "loss_weights": b["loss_weights"]}
        params, opt_state, m = train_step(params, opt_state, batch)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    return rcfg, cfg, model, params, (first, last)


def test_training_substrate_learns(trained):
    _, _, _, _, (first, last) = trained
    assert last < first, (first, last)


def test_lethe_serving_end_to_end(trained):
    rcfg, cfg, model, params, _ = trained
    b = pipeline.reasoning_batch(rcfg, 999)
    prompt = {"tokens": b["tokens"][:, :20]}

    full = Engine(model, params, make_policy("fullkv", capacity=128))
    lethe = Engine(model, params, make_policy(
        "lethe", capacity=24, sink_len=2, sparse_ratio=4.0))
    r_full = full.generate(prompt, 48, trace_live=True, collect_logits=True)
    r_lethe = lethe.generate(prompt, 48, trace_live=True,
                             collect_logits=True)

    # 1. memory: Lethe's cache is bounded, FullKV grows linearly
    assert r_lethe.cache_bytes < r_full.cache_bytes / 3
    assert max(r_lethe.live_token_trace) <= 24 * cfg.n_layers * rcfg.batch_size
    assert r_full.live_token_trace[-1] == max(r_full.live_token_trace)

    # 2. multi-round pruning happened (occupancy fell more than once)
    drops = int(np.sum(np.diff(r_lethe.live_token_trace) < 0))
    assert drops >= 2

    # 3. generation quality: Lethe's next-token distributions stay close to
    #    FullKV's on a trained model (KL sanity, not exactness)
    lp_f = jax.nn.log_softmax(jnp.asarray(r_full.logits_trace))
    lp_l = jax.nn.log_softmax(jnp.asarray(r_lethe.logits_trace))
    kl = float(jnp.mean(jnp.sum(jnp.exp(lp_f) * (lp_f - lp_l), -1)))
    assert np.isfinite(kl) and kl < 2.0, kl

    # 4. outputs are valid tokens
    assert (r_lethe.tokens >= 0).all()
    assert (r_lethe.tokens < cfg.vocab_size).all()


def test_layerwise_budgets_adapt(trained):
    """Spatial adaptivity: per-layer budgets must not stay uniform once the
    sparsity estimator has observed real attention."""
    rcfg, cfg, model, params, _ = trained
    pol = make_policy("lethe", capacity=32, sink_len=2, sparse_ratio=4.0)
    b = pipeline.reasoning_batch(rcfg, 123)
    _, state = model.prefill(params, {"tokens": b["tokens"][:, :24]}, pol)
    tok = jnp.zeros((rcfg.batch_size,), jnp.int32)
    for t in range(8):
        _, state = model.decode_step(params, state, tok,
                                     jnp.asarray(24 + t), pol)
    budgets = np.asarray(state.budget)
    spars = np.asarray(state.sparsity)
    assert np.isfinite(spars).all() and (spars >= 0).all()
    assert budgets.min() >= pol.sink_len
    # budgets respond to sparsity: not all equal unless sparsity is uniform
    if np.ptp(spars) > 1e-3:
        assert np.ptp(budgets) > 0, (budgets, spars)
