"""Crash-safe serving battery (DESIGN.md §Durability).

Four layers, cheapest first:

  * journal unit tests — checksummed JSONL append/read, torn-tail
    truncation, digest watermarks, offset-gap detection;
  * checkpoint unit tests — atomic tmp+rename visibility (a simulated
    crash between rows and manifest leaves only an ignored partial),
    keep-last-K pruning, fingerprint compatibility gating;
  * serialization matrix — ``ckpt.save_rows``/``load_rows`` round-trips
    slot snapshots bitwise for EVERY policy family (LazyEviction armed
    counters, G-KV undecayed scores, int8 payload+scales) without
    touching a model, plus a mesh-sharded extract on multi-device hosts;
  * end-to-end kill-point harness — a run is crashed deterministically at
    each instrumented boundary (after_admit, mid_segment, after_harvest,
    mid_checkpoint), recovered in a fresh core, and the client-reconnect
    stream (journal's durable tokens + post-recovery live emission) must
    be bitwise identical to an undisturbed run: no token lost, none
    emitted twice, exactly one terminal per request. The transient-fault
    retry ladder and quarantine ride the same fixtures.
"""
import json
import os
import signal
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.core import cache as cache_lib
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving import durability as dur_lib
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, ChaosConfig,
                                     FrontDoorCore, RetryConfig,
                                     ServeRequest)
from repro.serving.prefix_cache import PrefixCache, PrefixCacheConfig

pytestmark = pytest.mark.durability

INF = float("inf")
SPEC = [(8, 26), (10, 30), (12, 24)]
KILL_POINTS = ("after_admit", "mid_segment", "after_harvest",
               "mid_checkpoint")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def eng(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    return Engine(model, params, pol)


@pytest.fixture(scope="module")
def baseline(setup, eng):
    """Fault-free tokens for SPEC — every durability run must reproduce
    these bitwise."""
    cfg, _, _ = setup
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent())
    core.submit(_reqs(cfg, SPEC))
    return {c.uid: list(c.tokens) for c in core.run()}


def _reqs(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(uid=i,
                         prompt=rng.integers(0, cfg.vocab_size,
                                             size=s).astype(np.int32),
                         max_new_tokens=n)
            for i, (s, n) in enumerate(spec)]


def _transparent():
    return AdmissionConfig(compress_at=INF, shed_at=INF, reject_at=INF)


def _rand_fill(tree, seed=0):
    """Random host values in each leaf's own dtype — bf16 leaves get real
    bf16 bit patterns, int8 payloads random bytes, int32 cursors random
    ints — so a round-trip that survives is exercising every dtype the
    pool actually stores."""
    rng = np.random.default_rng(seed)

    def one(x):
        x = np.asarray(x)
        if np.issubdtype(x.dtype, np.integer):
            lo, hi = (0, 127) if x.dtype == np.int8 else (-5, 1000)
            return rng.integers(lo, hi, size=x.shape).astype(x.dtype)
        return rng.standard_normal(x.shape).astype(x.dtype)
    return jax.tree.map(one, tree)


def _tree_equal(a, b, msg=""):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, f"{msg}: {x.dtype} != {y.dtype}"
        np.testing.assert_array_equal(x, y, err_msg=msg)


# --------------------------------------------------------------------------
# Journal
# --------------------------------------------------------------------------

def test_journal_append_read_roundtrip(tmp_path):
    path = str(tmp_path / "j.log")
    j = dur_lib.Journal(path)
    recs = [{"ev": "open", "fp": "ab" * 16},
            {"ev": "submit", "uid": 0, "prompt": [1, 2, 3], "n": 4,
             "pri": 0, "dl": None, "dt": None},
            {"ev": "tok", "uid": 0, "off": 0, "toks": [7, 8]}]
    for r in recs:
        j.append(r)
    j.close()
    got, good = dur_lib.read_journal(path)
    assert got == recs
    assert good == os.path.getsize(path)


def test_journal_torn_tail_truncated(tmp_path):
    path = str(tmp_path / "j.log")
    j = dur_lib.Journal(path)
    j.append({"ev": "open", "fp": "00"})
    j.append({"ev": "tok", "uid": 0, "off": 0, "toks": [1]})
    j.close()
    clean = os.path.getsize(path)
    with open(path, "ab") as f:       # torn append: no newline, no checksum
        f.write(b'{"ev": "tok", "uid": 0, "off"')
    recs, good = dur_lib.read_journal(path)
    assert len(recs) == 2 and good == clean


def test_journal_corrupt_line_stops_read(tmp_path):
    path = str(tmp_path / "j.log")
    j = dur_lib.Journal(path)
    for i in range(4):
        j.append({"ev": "tok", "uid": 0, "off": i, "toks": [i]})
    j.close()
    raw = open(path, "rb").read()
    lines = raw.splitlines(keepends=True)
    # flip a payload byte of line 2: its checksum no longer matches, so
    # it AND everything after it is discarded (append-only semantics)
    bad = bytearray(lines[2])
    bad[10] ^= 0xFF
    open(path, "wb").write(b"".join(lines[:2]) + bytes(bad) + lines[3])
    recs, good = dur_lib.read_journal(path)
    assert len(recs) == 2
    assert good == len(lines[0]) + len(lines[1])


def test_digest_watermark_terminals_outstanding():
    recs = [
        {"ev": "open", "fp": "00"},
        {"ev": "submit", "uid": 0, "prompt": [1], "n": 8, "pri": 0,
         "dl": None, "dt": None},
        {"ev": "submit", "uid": 1, "prompt": [2], "n": 8, "pri": 0,
         "dl": None, "dt": None},
        {"ev": "admit", "uid": 0},
        {"ev": "tok", "uid": 0, "off": 0, "toks": [5, 6]},
        {"ev": "tok", "uid": 0, "off": 2, "toks": [7]},
        # idempotent overlap: a recovered run re-journals an old suffix
        {"ev": "tok", "uid": 0, "off": 1, "toks": [6, 7, 8]},
        {"ev": "end", "uid": 1, "reason": "rejected", "detail": None},
    ]
    dig = dur_lib.digest_journal(recs)
    assert dig.tokens[0] == [5, 6, 7, 8]
    assert dig.watermark(0) == 4 and dig.watermark(1) == 0
    assert dig.terminal[1] == ("rejected", None)
    assert dig.outstanding() == [0]
    assert not dig.sealed


def test_digest_offset_gap_is_typed_error():
    recs = [{"ev": "submit", "uid": 0, "prompt": [1], "n": 8, "pri": 0,
             "dl": None, "dt": None},
            {"ev": "tok", "uid": 0, "off": 0, "toks": [5]},
            {"ev": "tok", "uid": 0, "off": 3, "toks": [9]}]   # hole at 1-2
    with pytest.raises(ValueError, match="gap"):
        dur_lib.digest_journal(recs)


# --------------------------------------------------------------------------
# Checkpoints (no model: synthetic pool rows)
# --------------------------------------------------------------------------

def _fake_rows(kind="lethe", kv_format="bf16", seed=0):
    pol = make_policy(kind, capacity=8, kv_format=kv_format)
    state = cache_lib.init_cache(n_layers=2, batch=1, n_kv_heads=2,
                                 capacity=8, d_head=4, policy=pol)
    return _rand_fill(cache_lib.extract_slots(state, [0]), seed=seed)


def _entry(uid, seed):
    return (uid, _fake_rows(seed=seed), 7 + uid, 11 + uid, 3 + uid)


def test_checkpoint_roundtrip_and_prune(tmp_path):
    root = str(tmp_path)
    fp = b"\x01" * 16
    for seq in (1, 2, 3):
        dur_lib.write_checkpoint(root, seq, fp,
                                 [_entry(0, seq), _entry(1, seq + 10)],
                                 keep=2)
    assert dur_lib.list_checkpoints(root) == [2, 3]   # keep-last-K
    donor = _fake_rows()
    ck = dur_lib.load_checkpoint(root, 3, donor)
    assert ck.seq == 3 and set(ck.uids) == {0, 1}
    assert ck.tok[1] == 8 and ck.pos[1] == 12 and ck.n_tokens[1] == 4
    _tree_equal(ck.row_for(0), _fake_rows(seed=3), "uid0 row")
    _tree_equal(ck.row_for(1), _fake_rows(seed=13), "uid1 row")


def test_checkpoint_mid_crash_leaves_no_visible_partial(tmp_path):
    root = str(tmp_path)
    fp = b"\x02" * 16
    dur_lib.write_checkpoint(root, 1, fp, [_entry(0, 0)], keep=4)

    def crash(point):
        if point == "mid_checkpoint":
            raise dur_lib.SimulatedCrash(point)
    with pytest.raises(dur_lib.SimulatedCrash):
        dur_lib.write_checkpoint(root, 2, fp, [_entry(0, 1)], keep=4,
                                 crash=crash)
    assert dur_lib.list_checkpoints(root) == [1]      # partial invisible
    ck = dur_lib.latest_compatible_checkpoint(root, fp, _fake_rows())
    assert ck is not None and ck.seq == 1


def test_checkpoint_fingerprint_gates_compat(tmp_path):
    root = str(tmp_path)
    dur_lib.write_checkpoint(root, 1, b"\x03" * 16, [_entry(0, 0)], keep=4)
    dur_lib.write_checkpoint(root, 2, b"\x04" * 16, [_entry(0, 1)], keep=4)
    donor = _fake_rows()
    # newest wins among matches; a mismatched newer one is skipped
    ck = dur_lib.latest_compatible_checkpoint(root, b"\x03" * 16, donor)
    assert ck is not None and ck.seq == 1
    assert dur_lib.latest_compatible_checkpoint(root, b"\x05" * 16,
                                                donor) is None


# --------------------------------------------------------------------------
# Snapshot serialization matrix: every policy family x kv_format
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kv_format", ["bf16", "int8"])
@pytest.mark.parametrize("kind", ["fullkv", "lethe", "h2o", "streaming",
                                  "pyramidkv", "lazyeviction", "gkv"])
def test_rows_disk_roundtrip_every_policy(tmp_path, kind, kv_format):
    """extract_slots rows -> save_rows -> load_rows must be BITWISE for
    every policy family's aux state (LazyEviction (budget, evict_at)
    armed pairs, G-KV undecayed score mass, int8 payload+scales) — this
    is what makes checkpoint-resume indistinguishable from never having
    crashed."""
    rows = _fake_rows(kind, kv_format, seed=17)
    path = str(tmp_path / "rows")
    ckpt.save_rows(path, rows)
    back = ckpt.load_rows(path, _fake_rows(kind, kv_format, seed=0))
    _tree_equal(back, rows, f"{kind}/{kv_format}")


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="mesh round-trip needs >= 4 devices; run under "
                           "XLA_FLAGS=--xla_force_host_platform_device"
                           "_count=8")
def test_rows_disk_roundtrip_under_mesh(tmp_path, setup):
    """A mesh-sharded live state extracts to host rows that round-trip
    bitwise — checkpoints taken on a sharded server restore on any
    topology whose fingerprint matches."""
    from repro.serving.meshing import ServingMesh
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    mesh = ServingMesh.build("2,2")
    eng = Engine(model, params, pol, mesh=mesh)
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent())
    core.submit(_reqs(cfg, [(8, 6), (10, 6)]))
    core.step()
    core.step()
    rows = cache_lib.extract_slots(core.state, [0, 1])
    path = str(tmp_path / "rows")
    ckpt.save_rows(path, rows)
    donor = cache_lib.extract_slots(eng.new_decode_state(2), [0, 1])
    _tree_equal(ckpt.load_rows(path, donor), rows, "mesh rows")


# --------------------------------------------------------------------------
# End-to-end: durable run, kill points, recovery
# --------------------------------------------------------------------------

def test_durable_run_matches_baseline_and_journal(tmp_path, setup, eng,
                                                  baseline):
    cfg, _, _ = setup
    root = str(tmp_path / "dur")
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent(),
                         durability=dur_lib.DurabilityConfig(
                             root=root, checkpoint_every=2))
    core.submit(_reqs(cfg, SPEC))
    out = {c.uid: c for c in core.run()}
    for u in baseline:
        np.testing.assert_array_equal(out[u].tokens, baseline[u])
    recs, _ = dur_lib.read_journal(os.path.join(root,
                                                dur_lib.JOURNAL_NAME))
    dig = dur_lib.digest_journal(recs)
    assert len(dig.terminal) == len(SPEC)
    for u in baseline:           # write-ahead: journal == emitted stream
        np.testing.assert_array_equal(dig.tokens[u], baseline[u],
                                      err_msg=f"journal uid {u}")
    assert dur_lib.list_checkpoints(root)
    s = core.run_summary()["durability"]
    assert s["checkpoints_written"] > 0 and not s["sealed"]


def _crash_and_recover(cfg, eng, root, point, baseline, *, pre_steps=3,
                       expect_ckpt=True):
    """Run SPEC under durability, SIGKILL-simulate at ``point``, recover
    in a fresh core, and assert the client-reconnect stream contract."""
    d = dur_lib.Durability(dur_lib.DurabilityConfig(root=root,
                                                    checkpoint_every=2))
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent(), durability=d)
    core.submit(_reqs(cfg, SPEC))
    emitted: dict[int, list[int]] = {}
    for _ in range(pre_steps):       # get past a completed checkpoint
        ev, _ = core.step()
        for uid, toks in ev:
            emitted.setdefault(uid, []).extend(toks)
    if expect_ckpt:
        assert dur_lib.list_checkpoints(root), point
    d.crash_points.add(point)
    with pytest.raises(dur_lib.SimulatedCrash):
        while not core.idle:
            ev, _ = core.step()
            for uid, toks in ev:
                emitted.setdefault(uid, []).extend(toks)

    core2, report = dur_lib.recover(eng, root, batch_slots=2,
                                    segment_len=4,
                                    admission=_transparent())
    assert report["journal_truncated_bytes"] == 0
    # client reconnect: everything observed pre-crash is a prefix of the
    # journal's durable stream (nothing acked was lost) ...
    streams: dict[int, list[int]] = {}
    for u, durable in report["durable_tokens"].items():
        pre = emitted.get(u, [])
        assert durable[:len(pre)] == pre, (point, u)
        streams[u] = list(durable)
    # ... and live emission continues from the watermark, no overlap
    while not core2.idle:
        ev, _ = core2.step()
        for uid, toks in ev:
            streams.setdefault(uid, []).extend(toks)
    recovered = {c.uid for c in core2.completed}
    pre_terms = {c.uid for c in core.completed}
    assert not (pre_terms & recovered), point      # exactly-once terminal
    assert pre_terms | recovered == set(baseline), point
    for u, toks in baseline.items():
        np.testing.assert_array_equal(streams[u], toks,
                                      err_msg=f"{point}: stream uid {u}")
    return report


@pytest.mark.parametrize("point", KILL_POINTS)
def test_kill_point_stream_bitexact(tmp_path, setup, eng, baseline, point):
    cfg, _, _ = setup
    report = _crash_and_recover(cfg, eng, str(tmp_path / point), point,
                                baseline)
    assert (report["resumed_from_checkpoint"]
            + report["replayed_from_prompt"]) == report["outstanding"]
    assert report["resumed_from_checkpoint"] > 0    # checkpoint was used


@pytest.mark.parametrize("kind,kv_format,point", [
    ("h2o", "bf16", "mid_segment"),
    ("lazyeviction", "bf16", "after_admit"),
    ("lethe", "int8", "after_harvest"),
    ("h2o", "int8", "mid_checkpoint"),
    ("lazyeviction", "int8", "mid_segment"),
])
def test_kill_point_policy_matrix(tmp_path, setup, kind, kv_format, point):
    """Crash-recovery is policy-blind: the checkpoint carries whatever aux
    state the family keeps (H2O accumulators, LazyEviction armed pairs,
    int8 scales) and the recovered stream is still bitwise identical."""
    cfg, model, params = setup
    pol = make_policy(kind, capacity=24, sink_len=2, sparse_ratio=4.0,
                      kv_format=kv_format)
    eng = Engine(model, params, pol)
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent())
    core.submit(_reqs(cfg, SPEC))
    base = {c.uid: list(c.tokens) for c in core.run()}
    _crash_and_recover(cfg, eng, str(tmp_path / "d"), point, base)


def test_recover_after_graceful_seal_is_clean(tmp_path, setup, eng):
    """shutdown() mid-run journals + checkpoints + seals; recover() then
    resumes the outstanding half and finishes it bitwise."""
    cfg, _, _ = setup
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent())
    core.submit(_reqs(cfg, SPEC))
    base = {c.uid: list(c.tokens) for c in core.run()}

    root = str(tmp_path / "dur")
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent(),
                         durability=dur_lib.DurabilityConfig(
                             root=root, checkpoint_every=2))
    core.submit(_reqs(cfg, SPEC))
    streams: dict[int, list[int]] = {}
    for _ in range(3):
        ev, _ = core.step()
        for uid, toks in ev:
            streams.setdefault(uid, []).extend(toks)
    info = core.shutdown(checkpoint=True)          # SIGTERM path
    assert info["checkpoint_seq"] is not None
    assert info["live"] + info["queued"] > 0

    core2, report = dur_lib.recover(eng, root, batch_slots=2,
                                    segment_len=4,
                                    admission=_transparent())
    assert report["sealed"]
    assert report["resumed_from_checkpoint"] == info["live"]
    while not core2.idle:
        ev, _ = core2.step()
        for uid, toks in ev:
            streams.setdefault(uid, []).extend(toks)
    done = {c.uid for c in core.completed} | {c.uid
                                              for c in core2.completed}
    assert done == set(base)
    for u, toks in base.items():
        np.testing.assert_array_equal(streams[u], toks,
                                      err_msg=f"uid {u}")


def test_double_crash_recovery_still_bitexact(tmp_path, setup, eng,
                                              baseline):
    """Crash DURING recovery's own serving run: absolute token offsets
    mean the watermark survives any number of crashes."""
    cfg, _, _ = setup
    root = str(tmp_path / "dur")
    d = dur_lib.Durability(dur_lib.DurabilityConfig(root=root,
                                                    checkpoint_every=2))
    core = FrontDoorCore(eng, batch_slots=2, segment_len=4,
                         admission=_transparent(), durability=d)
    core.submit(_reqs(cfg, SPEC))
    streams: dict[int, list[int]] = {}
    for _ in range(3):
        ev, _ = core.step()
        for uid, toks in ev:
            streams.setdefault(uid, []).extend(toks)
    d.crash_points.add("after_harvest")
    with pytest.raises(dur_lib.SimulatedCrash):
        while not core.idle:
            core.step()          # post-crash emissions lost on the wire

    core2, rep2 = dur_lib.recover(eng, root, batch_slots=2, segment_len=4,
                                  admission=_transparent())
    core2.dur.crash_points.add("mid_segment")
    with pytest.raises(dur_lib.SimulatedCrash):
        while not core2.idle:
            core2.step()

    core3, rep3 = dur_lib.recover(eng, root, batch_slots=2, segment_len=4,
                                  admission=_transparent())
    streams = {u: list(t) for u, t in rep3["durable_tokens"].items()}
    while not core3.idle:
        ev, _ = core3.step()
        for uid, toks in ev:
            streams.setdefault(uid, []).extend(toks)
    done = ({c.uid for c in core.completed}
            | {c.uid for c in core2.completed}
            | {c.uid for c in core3.completed})
    assert done == set(baseline)
    for u, toks in baseline.items():
        np.testing.assert_array_equal(streams[u], toks,
                                      err_msg=f"uid {u}")


# --------------------------------------------------------------------------
# Transient-fault retry ladder
# --------------------------------------------------------------------------

@pytest.mark.parametrize("field", ["nan_logits_at", "fault_at"])
def test_transient_fault_retries_to_bitexact_completion(setup, eng,
                                                        baseline, field):
    """A one-shot fault rolls the row back to its pre-segment snapshot and
    the retry completes the request with IDENTICAL tokens — the fault is
    invisible except in the retry counters."""
    cfg, _, _ = setup
    core = FrontDoorCore(eng, batch_slots=3, segment_len=4,
                         admission=_transparent(),
                         chaos=ChaosConfig(**{field: {1: 5}}),
                         retry=RetryConfig(max_retries=3))
    core.submit(_reqs(cfg, SPEC))
    out = {c.uid: c for c in core.run()}
    s = core.run_summary()
    assert out[1].finish_reason in ("eos", "length")
    assert out[1].retries == 1 and s["retries"] == 1
    assert s["failed"] == 0 and not s["quarantined_slots"]
    for u in baseline:
        np.testing.assert_array_equal(out[u].tokens, baseline[u],
                                      err_msg=f"uid {u}")


def test_persistent_fault_exhausts_retries_and_quarantines(setup, eng,
                                                           baseline):
    cfg, _, _ = setup
    core = FrontDoorCore(eng, batch_slots=3, segment_len=4,
                         admission=_transparent(),
                         chaos=ChaosConfig(fault_at={1: 5},
                                           persistent=True),
                         retry=RetryConfig(max_retries=2))
    core.submit(_reqs(cfg, SPEC))
    out = {c.uid: c for c in core.run()}
    s = core.run_summary()
    assert out[1].finish_reason == "failed"
    assert out[1].failure_detail == "retry_exhausted"
    assert out[1].retries == 2 == s["retries"]
    assert s["failure_details"] == {"retry_exhausted": 1}
    assert s["quarantined_slots"]          # broken slot out of rotation
    for u in (0, 2):                       # survivors untouched
        np.testing.assert_array_equal(out[u].tokens, baseline[u],
                                      err_msg=f"survivor uid {u}")


def test_retry_disabled_fails_fast_with_typed_detail(setup, eng):
    cfg, _, _ = setup
    core = FrontDoorCore(eng, batch_slots=3, segment_len=4,
                         admission=_transparent(),
                         chaos=ChaosConfig(nan_logits_at={1: 5}))
    core.submit(_reqs(cfg, SPEC))
    out = {c.uid: c for c in core.run()}
    s = core.run_summary()
    assert out[1].finish_reason == "failed"
    assert out[1].failure_detail == "nan_logits"
    assert s["failure_details"] == {"nan_logits": 1}
    assert s["retries"] == 0 and not s["quarantined_slots"]


# --------------------------------------------------------------------------
# Prefix-store disk persistence
# --------------------------------------------------------------------------

def test_prefix_store_save_load_roundtrip(tmp_path):
    store = PrefixCache(PrefixCacheConfig(max_bytes=1 << 24, block_size=4,
                                          min_tokens=4))
    fp = b"\x07" * 16
    toks_a = np.arange(8, dtype=np.int32)
    toks_b = np.arange(100, 112, dtype=np.int32)
    rows_a = _fake_rows(seed=1)
    rows_b = _fake_rows("h2o", seed=2)
    assert store.insert(fp, toks_a, rows_a, first_token=42)
    assert store.insert(fp, toks_b, rows_b, first_token=43)
    path = str(tmp_path / "prefixes")
    assert store.save(path) == 2

    fresh = PrefixCache(PrefixCacheConfig(max_bytes=1 << 24, block_size=4,
                                          min_tokens=4))
    assert fresh.load(path, _fake_rows(seed=0)) == 2
    for toks, rows, first in ((toks_a, rows_a, 42), (toks_b, rows_b, 43)):
        hit = fresh.lookup(fp, toks)
        assert hit is not None and hit.full
        assert hit.entry.first_token == first
        _tree_equal(hit.entry.rows, rows, "entry")
    assert fresh.stats()["load_skipped"] == 0
    # idempotent: loading again adds nothing
    assert fresh.load(path, _fake_rows(seed=0)) == 0


def test_prefix_store_load_skips_incompatible(tmp_path):
    """An int8 store loaded by a bf16 engine (or a mangled meta) must be
    SKIPPED, never coerced — a structure-blind unpack would silently drop
    the scale leaves and poison later admissions."""
    store = PrefixCache(PrefixCacheConfig(max_bytes=1 << 24, block_size=4,
                                          min_tokens=4))
    store.insert(b"\x08" * 16, np.arange(8, dtype=np.int32),
                 _fake_rows(kv_format="int8", seed=3), first_token=5)
    path = str(tmp_path / "prefixes")
    store.save(path)
    fresh = PrefixCache(PrefixCacheConfig(max_bytes=1 << 24))
    assert fresh.load(path, _fake_rows(seed=0)) == 0   # bf16 donor
    assert fresh.stats()["load_skipped"] == 1

    meta = json.load(open(path + ".meta.json"))
    meta["entries"][0]["rows_meta"]["keys"] = ["e0/nonexistent"]
    json.dump(meta, open(path + ".meta.json", "w"))
    fresh2 = PrefixCache(PrefixCacheConfig(max_bytes=1 << 24))
    assert fresh2.load(path, _fake_rows(kv_format="int8", seed=0)) == 0
    assert fresh2.stats()["load_skipped"] == 1


# --------------------------------------------------------------------------
# Process-level: SIGTERM graceful drain + --recover restart
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_sigterm_drain_then_recover(tmp_path):
    """Real signals against the real launcher: SIGTERM mid-decode exits 0
    after checkpoint+seal; ``--recover`` finishes every outstanding
    request in a new process."""
    root = str(tmp_path / "dur")
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    args = [sys.executable, "-u", "-m", "repro.launch.serve",
            "--arch", "qwen2.5-32b", "--reduced", "--policy", "lethe",
            "--capacity", "24", "--slots", "2", "--segment-len", "4",
            "--prompt-len", "8", "--gen", "400", "--requests", "4",
            "--durability-dir", root, "--checkpoint-every", "2"]
    p = subprocess.Popen(args, env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT, text=True)
    out, deadline = [], time.monotonic() + 300
    for line in p.stdout:            # wait for live decode, then SIGTERM
        out.append(line)
        if "tok[" in line:
            p.send_signal(signal.SIGTERM)
            break
        assert time.monotonic() < deadline
    rest, _ = p.communicate(timeout=300)
    out = "".join(out) + rest
    assert p.returncode == 0, out
    assert "graceful drain" in out and "drained:" in out, out
    assert dur_lib.list_checkpoints(root), out

    r = subprocess.run(args + ["--recover", "--requests", "0"], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "recovery:" in r.stdout, r.stdout
    recs, _ = dur_lib.read_journal(os.path.join(root,
                                                dur_lib.JOURNAL_NAME))
    dig = dur_lib.digest_journal(recs)
    assert len(dig.terminal) == 4            # every request terminated
    assert dig.outstanding() == [] and dig.sealed
    for u, (reason, _) in dig.terminal.items():
        assert reason == "length", (u, reason)
        assert len(dig.tokens[u]) == 400
