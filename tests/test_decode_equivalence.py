"""Incremental decode under FullKV must reproduce the parallel (teacher-
forced) forward logits exactly — the strongest correctness check on the
cache/attention/decode plumbing, run for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model

B, S, TAIL = 2, 20, 5


@pytest.mark.parametrize("name", [
    "qwen2.5-32b",        # dense GQA + bias
    "command-r-35b",      # parallel block, layernorm, tied
    "gemma2-27b",         # local/global + softcaps + sandwich
    "granite-20b",        # MQA
    "mixtral-8x7b",       # MoE + SWA
    "arctic-480b",        # MoE + dense residual
    "rwkv6-7b",           # SSM
    "recurrentgemma-2b",  # hybrid
    "whisper-large-v3",   # enc-dec
    "qwen2-vl-2b",        # M-RoPE VLM
])
def test_decode_matches_parallel(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    kw = {"max_positions": 64} if cfg.is_encoder_decoder else {}
    params = model.init(jax.random.PRNGKey(0), **kw)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
    s_img = 0
    if cfg.family == "audio":
        batch["enc_frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model))
    if cfg.family == "vlm":
        s_img = 4
        batch["img_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (B, s_img, cfg.d_model))

    full_logits, _ = model.forward_train(params, batch)  # [B, s_img+S, V]

    pol = make_policy("fullkv", capacity=S + s_img + 4)
    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :S - TAIL]
    logits, state = model.prefill(params, prompt, pol)
    np.testing.assert_allclose(
        np.asarray(logits),
        np.asarray(full_logits[:, s_img + S - TAIL - 1]),
        rtol=2e-4, atol=2e-4)

    for t in range(TAIL):
        tok = batch["tokens"][:, S - TAIL + t]
        cur = jnp.asarray(s_img + S - TAIL + t, jnp.int32)
        logits, state = model.decode_step(params, state, tok, cur, pol)
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(full_logits[:, s_img + S - TAIL + t]),
            rtol=2e-4, atol=2e-4,
            err_msg=f"{name} decode step {t} diverged from parallel forward")
