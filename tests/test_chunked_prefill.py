"""Chunked-prefill differential battery (DESIGN.md §Prefill).

The guarantees this file enforces:
  * differential — chunked admission is bit-identical to whole-prompt
    admission (first tokens, per-layer budgets, RASR scores, every cache
    tensor) across policies, model families, and chunk plans that do and
    do not divide the prompt length;
  * compression — prompts up to 2x capacity complete through prefill-phase
    eviction under every pruning policy, and FullKV rejects them;
  * stall-freedom — with chunked admission, at most one prefill chunk runs
    per decode segment while any row decodes, live rows advance every
    segment, and TTFT degrades monotonically and boundedly vs the
    whole-prompt baseline;
  * PR-2 invariants survive — continuous tokens == solo generate, every
    request completes exactly once, per-slot occupancy never exceeds
    capacity (hypothesis fuzz + seeded fallback);
  * retraces — a refill wave over many distinct prompt lengths reuses one
    program per power-of-two chunk shape (no per-length recompile).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv6_mod
from repro.models import transformer as transformer_mod
from repro.models import whisper as whisper_mod
from repro.models.api import build_model
from repro.serving.engine import Engine, chunk_plan
from repro.serving.scheduler import FINISHED, Request, Scheduler


@pytest.fixture(scope="module")
def qwen():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def whisper():
    cfg = get_arch("whisper-large-v3").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _policy(kind, capacity=24, **kw):
    kw.setdefault("sink_len", 2)
    kw.setdefault("sparse_ratio", 4.0)
    kw.setdefault("target_fill", 0.5)
    return make_policy(kind, capacity=capacity, **kw)


def _tokens(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    size=(B, S)).astype(np.int32))


def _assert_tree_equal(a, b, err=""):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, la), (_, lb) in zip(fa, fb):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{err}{jax.tree_util.keystr(pa)}")


# --------------------------------------------------------------------------
# chunk planning
# --------------------------------------------------------------------------

def test_chunk_plan_pow2_decomposition():
    for s in range(1, 70):
        for budget in (1, 3, 4, 8, 16):
            plan = chunk_plan(s, budget)
            assert sum(plan) == s
            assert all(n & (n - 1) == 0 for n in plan)       # powers of two
            assert max(plan) <= budget
    # the whole distinct-shape universe for one budget is O(log budget)
    shapes = {n for s in range(1, 200) for n in chunk_plan(s, 8)}
    assert shapes <= {1, 2, 4, 8}


# --------------------------------------------------------------------------
# Differential: chunked == whole, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming",
                                  "lazyeviction", "gkv"])
@pytest.mark.parametrize("plan", [(4, 4, 4),     # divides S=12
                                  (8, 4),        # does not divide
                                  (12,)])        # single chunk
def test_chunked_prefill_matches_whole_qwen(qwen, kind, plan):
    cfg, model, params = qwen
    pol = _policy(kind)
    batch = {"tokens": _tokens(cfg, 2, 12, seed=hash(kind) % 100)}
    lw, sw = model.prefill(params, batch, pol)
    lc, sc = model.prefill_chunked(params, batch, pol, chunk_plan=plan)
    np.testing.assert_array_equal(np.asarray(lw), np.asarray(lc))
    _assert_tree_equal(sw, sc, err=f"{kind}/{plan}: ")


@pytest.mark.parametrize("kind", ["lethe", "h2o"])
def test_chunked_prefill_matches_whole_whisper(whisper, kind):
    cfg, model, params = whisper
    pol = _policy(kind)
    rng = np.random.default_rng(3)
    batch = {"tokens": _tokens(cfg, 2, 11, seed=5),
             "enc_frames": jnp.asarray(rng.standard_normal(
                 (2, 16, cfg.d_model)).astype(np.float32))}
    lw, sw = model.prefill(params, batch, pol)
    # 11 = 8 + 2 + 1: a final partial-chunk cascade
    lc, sc = model.prefill_chunked(params, batch, pol, chunk_plan=(8, 2, 1))
    np.testing.assert_array_equal(np.asarray(lw), np.asarray(lc))
    _assert_tree_equal(sw, sc, err=f"whisper/{kind}: ")


def test_chunked_prefill_matches_whole_rwkv6():
    cfg = get_arch("rwkv6-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = {"tokens": _tokens(cfg, 2, 12, seed=7)}
    pol = _policy("lethe")
    lw, sw = model.prefill(params, batch, pol)
    lc, sc = model.prefill_chunked(params, batch, pol, chunk_plan=(4, 4, 4))
    np.testing.assert_array_equal(np.asarray(lw), np.asarray(lc))
    _assert_tree_equal(sw, sc, err="rwkv6: ")   # sequential scan: exact


def test_chunked_prefill_matches_whole_rglru():
    """RG-LRU runs ``associative_scan`` whose reduction tree depends on the
    chunk split — hidden states agree to float tolerance, tokens exactly."""
    cfg = get_arch("recurrentgemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    batch = {"tokens": _tokens(cfg, 2, 12, seed=9)}
    pol = _policy("lethe")
    lw, sw = model.prefill(params, batch, pol)
    lc, sc = model.prefill_chunked(params, batch, pol, chunk_plan=(8, 4))
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lw, -1)),
                                  np.asarray(jnp.argmax(lc, -1)))
    np.testing.assert_allclose(np.asarray(lw), np.asarray(lc),
                               rtol=1e-4, atol=1e-4)
    # discrete cache state is split-invariant even where floats are not
    np.testing.assert_array_equal(np.asarray(sw["kv"].pos),
                                  np.asarray(sc["kv"].pos))
    np.testing.assert_array_equal(np.asarray(sw["kv"].length),
                                  np.asarray(sc["kv"].length))
    for name in ("k", "v", "score"):
        np.testing.assert_allclose(
            np.asarray(getattr(sw["kv"], name)),
            np.asarray(getattr(sc["kv"], name)), rtol=1e-4, atol=1e-4,
            err_msg=name)


@pytest.mark.parametrize("chunk_size", [4, 8])
def test_chunked_admission_matches_whole_admission(qwen, chunk_size):
    """Engine-level: admit_slots_chunked leaves the live state bit-identical
    to admit_slots — including with dummy-row padding to full slot width."""
    cfg, model, params = qwen
    pol = _policy("lethe")
    eng = Engine(model, params, pol)
    B = 3
    batch = {"tokens": _tokens(cfg, 2, 12, seed=11)}

    state_w, first_w = eng.admit_slots(eng.new_decode_state(B), [0, 2],
                                       batch)
    state_c, first_c = eng.admit_slots_chunked(
        eng.new_decode_state(B), [0, 2], batch, chunk_size=chunk_size)
    np.testing.assert_array_equal(np.asarray(first_w), np.asarray(first_c))
    _assert_tree_equal(state_w, state_c, err="admission: ")

    state_p, first_p = eng.admit_slots_chunked(
        eng.new_decode_state(B), [0, 2], batch, chunk_size=chunk_size,
        pad_rows_to=B)
    np.testing.assert_array_equal(np.asarray(first_w), np.asarray(first_p))
    _assert_tree_equal(state_w, state_p, err="padded admission: ")


def test_prefill_chunk_donates_carry(qwen):
    """PR-1-style: each chunk step consumes its carry — the working buffers
    update in place across the chunk stream."""
    cfg, model, params = qwen
    eng = Engine(model, params, _policy("lethe"))
    job = eng.start_prefill_chunked({"tokens": _tokens(cfg, 1, 12, seed=13)},
                                    chunk_size=4)
    old_k = job.carry["buf"].k
    job = eng.prefill_chunk_step(job)
    assert old_k.is_deleted()


# --------------------------------------------------------------------------
# Compression: prompts longer than capacity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming",
                                  "lazyeviction", "gkv"])
def test_long_prompt_compressed_prefill(qwen, kind):
    """Prompts up to 2x capacity stream through prefill-phase eviction:
    occupancy stays bounded, the sink and final tokens survive, and the
    resulting cache decodes."""
    cfg, model, params = qwen
    C = 16
    pol = _policy(kind, capacity=C)
    eng = Engine(model, params, pol)
    S = 2 * C
    batch = {"tokens": _tokens(cfg, 2, S, seed=17)}
    state, first = eng.admit_slots_chunked(
        eng.new_decode_state(2), [0, 1], batch, chunk_size=8)
    assert first.shape == (2,)
    lengths = np.asarray(state.length)
    assert lengths.max() <= C
    assert lengths.min() >= 1
    pos = np.asarray(state.pos)                        # [L, B, C]
    assert (pos == S - 1).any(axis=-1).all(), "final token evicted"
    assert (np.where(pos >= 0, pos, 10 ** 9) < pol.sink_len).any(axis=-1) \
        .all(), "sink tokens evicted"
    # the compressed cache must actually decode
    state, seg, _, _ = eng.decode_segment(
        state, np.asarray(first, np.int32), np.full((2,), S, np.int32),
        np.zeros((2,), bool), 4)
    seg = np.asarray(seg)
    assert ((seg >= 0) & (seg < cfg.vocab_size)).all()
    assert np.asarray(state.length).max() <= C


def test_long_prompt_fullkv_rejected(qwen):
    cfg, model, params = qwen
    eng = Engine(model, params, make_policy("fullkv", capacity=16))
    with pytest.raises(ValueError, match="cannot evict"):
        eng.start_prefill_chunked({"tokens": _tokens(cfg, 1, 20, seed=19)},
                                  chunk_size=8)


def test_scheduler_rejects_inadmissible_without_aborting(qwen):
    """One over-capacity arrival under a non-evicting policy must not abort
    the run: it is rejected as a Completion while every other request
    finishes normally."""
    cfg, model, params = qwen
    eng = Engine(model, params, make_policy("fullkv", capacity=32))
    rng = np.random.default_rng(41)
    ok = _requests(cfg, [(8, 5), (10, 7)], seed=41)
    bad = Request(uid=9, prompt=rng.integers(0, cfg.vocab_size,
                                             size=40).astype(np.int32),
                  max_new_tokens=4)
    sched = Scheduler(eng, batch_slots=2, segment_len=4,
                      prefill_chunk_size=8)
    sched.submit(ok + [bad])
    done = sched.run()
    assert sorted(c.uid for c in done) == [0, 1, 9]
    by_uid = {c.uid: c for c in done}
    assert by_uid[9].finish_reason == "rejected"
    assert len(by_uid[9].tokens) == 0
    assert len(by_uid[0].tokens) == 5 and len(by_uid[1].tokens) == 7
    assert sched.lifecycle[9][-1] == FINISHED


def test_chunk_flash_flag_matches_ref_admission(qwen, monkeypatch):
    """REPRO_CHUNK_FLASH=1 + interpret mode drives the Pallas flash
    q_offset path for contiguous chunks; the admitted tokens must match
    the slotted-oracle admission."""
    from repro.kernels import ops as ops_mod
    cfg, model, params = qwen
    pol = _policy("lethe")
    eng = Engine(model, params, pol)
    batch = {"tokens": _tokens(cfg, 1, 12, seed=43)}
    state_r, first_r = eng.admit_slots_chunked(
        eng.new_decode_state(2), [0], batch, chunk_size=4)
    monkeypatch.setenv("REPRO_CHUNK_FLASH", "1")
    ops_mod.set_default_impl("interpret")
    try:
        jax.clear_caches()
        state_f, first_f = eng.admit_slots_chunked(
            eng.new_decode_state(2), [0], batch, chunk_size=4)
    finally:
        ops_mod.set_default_impl("auto")
        jax.clear_caches()
    np.testing.assert_array_equal(np.asarray(first_r), np.asarray(first_f))
    np.testing.assert_allclose(np.asarray(state_f.k), np.asarray(state_r.k),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(state_f.pos),
                                  np.asarray(state_r.pos))


def test_long_prompt_budgets_respected_per_layer(qwen):
    """Prefill-phase eviction goes through decide_row: compressed rows end
    at (or under) their per-layer budget, not at an arbitrary cut."""
    cfg, model, params = qwen
    C = 16
    pol = _policy("h2o", capacity=C)
    eng = Engine(model, params, pol)
    state, _ = eng.admit_slots_chunked(
        eng.new_decode_state(1), [0], {"tokens": _tokens(cfg, 1, 30,
                                                         seed=23)},
        chunk_size=8)
    lengths = np.asarray(state.length)[:, 0]           # [L]
    budgets = np.asarray(state.budget)[:, 0]
    assert (lengths <= np.maximum(budgets, 1) + pol.sink_len).all()


# --------------------------------------------------------------------------
# Scheduler: stall-free interleave
# --------------------------------------------------------------------------

def _requests(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=s).astype(np.int32),
                    max_new_tokens=n)
            for i, (s, n) in enumerate(spec)]


def _solo(engine, req, eos_id=None):
    res = engine.generate({"tokens": jnp.asarray(req.prompt)[None, :]},
                          req.max_new_tokens, eos_id=eos_id)
    return np.asarray(res.tokens[0, :res.gen_lens[0]])


@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming"])
def test_scheduler_chunked_matches_solo(qwen, kind):
    """The PR-2 differential guarantee survives chunked admission:
    continuous tokens == solo generate, for every policy."""
    cfg, model, params = qwen
    eng = Engine(model, params, _policy(kind))
    reqs = _requests(cfg, [(8, 3), (12, 9), (8, 14), (12, 6), (8, 1),
                           (11, 7)], seed=29)
    solo = {r.uid: _solo(eng, r) for r in reqs}
    sched = Scheduler(eng, batch_slots=3, segment_len=4,
                      prefill_chunk_size=4)
    sched.submit(reqs)
    done = sched.run()
    assert [c.uid for c in done] == [r.uid for r in reqs]
    for c in done:
        np.testing.assert_array_equal(np.asarray(c.tokens), solo[c.uid],
                                      err_msg=f"uid {c.uid}")


def test_stall_bound_and_ttft_vs_whole_prompt(qwen):
    """The stall bound (at most one prefill chunk per decode segment while
    any row decodes) holds, and per-request TTFT in decode steps is
    monotone vs the whole-prompt baseline with a bounded gap."""
    cfg, model, params = qwen
    eng = Engine(model, params, _policy("lethe"))
    spec = [(8, 6), (12, 12), (8, 9), (12, 5), (8, 16), (12, 8)]
    chunk = 4

    sched_w = Scheduler(eng, batch_slots=2, segment_len=4)
    sched_w.submit(_requests(cfg, spec, seed=31))
    ttft_w = {c.uid: c.ttft_steps for c in sched_w.run()}

    sched_c = Scheduler(eng, batch_slots=2, segment_len=4,
                        prefill_chunk_size=chunk)
    sched_c.submit(_requests(cfg, spec, seed=31))
    done_c = sched_c.run()

    # stall bound: no decode segment waits on more than one chunk of
    # prefill work
    assert sched_c.prefill_boundary_trace, "no boundaries recorded"
    for rec in sched_c.prefill_boundary_trace:
        if rec["live"] > 0:
            assert rec["chunks"] <= 1, rec

    # TTFT monotonicity + bounded degradation: spreading prefill cannot
    # make a first token *earlier* in decode-step time, and costs at most
    # the workload's total chunk count in extra segments
    total_chunks = sum(len(chunk_plan(s, chunk)) for s, _ in spec)
    for c in done_c:
        assert c.ttft_steps >= ttft_w[c.uid], c.uid
        assert c.ttft_steps <= ttft_w[c.uid] \
            + total_chunks * sched_c.segment_len, c.uid


def test_scheduler_chunked_admits_long_prompts(qwen):
    """Mixed traffic where some prompts exceed capacity: the fit-capacity
    requests still reproduce solo generation exactly; the long ones
    complete through compressed prefill."""
    cfg, model, params = qwen
    C = 16
    eng = Engine(model, params, _policy("lethe", capacity=C))
    rng = np.random.default_rng(37)
    short = _requests(cfg, [(8, 5), (9, 8)], seed=37)
    long_reqs = [Request(uid=10 + i,
                         prompt=rng.integers(0, cfg.vocab_size,
                                             size=s).astype(np.int32),
                         max_new_tokens=6)
                 for i, s in enumerate((24, 31))]     # up to ~2x capacity
    solo = {r.uid: _solo(eng, r) for r in short}
    sched = Scheduler(eng, batch_slots=2, segment_len=4,
                      prefill_chunk_size=8, track_occupancy=True)
    sched.submit(short + long_reqs)
    done = sched.run()
    assert sorted(c.uid for c in done) == [0, 1, 10, 11]
    for c in done:
        if c.uid in solo:
            np.testing.assert_array_equal(np.asarray(c.tokens), solo[c.uid])
        else:
            assert len(c.tokens) == 6
    assert sched.max_slot_tokens <= C


# --------------------------------------------------------------------------
# Fuzz: PR-2 invariants under chunked admission (hypothesis + seeded)
# --------------------------------------------------------------------------

def _fuzz_case(setup, spec, slots, eos_id, chunk):
    """Random mixed short/long traffic through chunked admission: every uid
    completes exactly once within budget, occupancy never exceeds capacity,
    the stall bound holds, and the queue drains."""
    cfg, model, params = setup
    pol = _policy("lethe", capacity=16, sparse_ratio=3.0)
    eng = Engine(model, params, pol)
    reqs = _requests(cfg, spec, seed=len(spec))
    sched = Scheduler(eng, batch_slots=slots, segment_len=3, eos_id=eos_id,
                      track_occupancy=True, prefill_chunk_size=chunk)
    sched.submit(reqs)
    done = sched.run()

    assert [c.uid for c in done] == list(range(len(reqs)))
    for c, r in zip(done, reqs):
        assert 1 <= len(c.tokens) <= r.max_new_tokens
        if c.finish_reason == "eos":
            assert c.tokens[-1] == eos_id
            assert not (c.tokens[:-1] == eos_id).any()
        else:
            assert len(c.tokens) == r.max_new_tokens
        assert sched.lifecycle[r.uid].count(FINISHED) == 1
    assert sched.max_slot_tokens <= pol.capacity
    for rec in sched.prefill_boundary_trace:
        if rec["live"] > 0:
            assert rec["chunks"] <= 1, rec
    assert not sched.queue


# prompt lengths: short mixes + lengths beyond the capacity of 16
_LENS, _MAXNEW = (4, 6, 9, 20, 27), (1, 10)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    _REQ = st.tuples(st.sampled_from(_LENS), st.integers(*_MAXNEW))

    @settings(max_examples=6, deadline=None)
    @given(st.lists(_REQ, min_size=1, max_size=8),
           st.sampled_from([1, 2, 3]),
           st.sampled_from([None, 0, 3]),
           st.sampled_from([3, 4, 8]))
    def test_fuzz_chunked_no_starvation_no_overflow(qwen, spec, slots,
                                                    eos_id, chunk):
        _fuzz_case(qwen, spec, slots, eos_id, chunk)
except ImportError:                          # pragma: no cover
    pass                                     # seeded sweep below still runs


@pytest.mark.parametrize("case_seed,slots,eos_id,chunk",
                         [(0, 1, None, 4), (1, 2, 3, 8), (2, 3, 0, 3),
                          (3, 2, None, 4)])
def test_seeded_chunked_random_mixes(qwen, case_seed, slots, eos_id, chunk):
    rng = np.random.default_rng(case_seed)
    n = int(rng.integers(1, 9))
    spec = [(int(rng.choice(_LENS)), int(rng.integers(*_MAXNEW) + 1))
            for _ in range(n)]
    _fuzz_case(qwen, spec, slots, eos_id, chunk)


# --------------------------------------------------------------------------
# Retrace regression: O(log chunk) programs per refill wave
# --------------------------------------------------------------------------

def test_no_per_length_recompile_across_refill_waves(qwen):
    """A second refill wave of entirely new prompt lengths must compile
    nothing: chunk programs are keyed by the power-of-two chunk shape (the
    offset is traced), finalize by the shared observation window."""
    cfg, model, params = qwen
    pol = _policy("lethe", obs_window=4)       # every length >= 4 shares it
    eng = Engine(model, params, pol)
    chunk = 4

    def admit_wave(lengths, seed):
        state = eng.new_decode_state(2)
        for j, s in enumerate(lengths):
            state, _ = eng.admit_slots_chunked(
                state, [j % 2], {"tokens": _tokens(cfg, 1, s, seed=seed + j)},
                chunk_size=chunk, pad_rows_to=2)

    from repro.models import chunked as chunked_mod

    def sizes():
        return (transformer_mod.prefill_chunk._cache_size(),
                chunked_mod.finalize_pipeline._cache_size(),
                transformer_mod._head._cache_size(),
                transformer_mod.prefill_chunk_init._cache_size())

    pre = sizes()
    admit_wave([5, 6, 9, 12], seed=100)        # warm every chunk shape
    warm = sizes()
    # the warm set is logarithmic in the chunk budget: chunk shapes
    # {1, 2, 4} at one batch width; one finalize pipeline per pow2 length
    # bucket ({8, 16} here); one logits head; one init
    assert warm[0] - pre[0] <= 3, (pre, warm)
    assert warm[1] - pre[1] <= 2, (pre, warm)
    assert warm[2] - pre[2] <= 1 and warm[3] - pre[3] <= 1, (pre, warm)
    admit_wave([7, 8, 10, 11, 13, 14, 15], seed=200)   # all-new lengths
    after = sizes()
    assert after == warm, f"refill wave retraced: {warm} -> {after}"
