"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (≤2 layers, d_model ≤ 512, ≤4 experts) runs one forward and
one train step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.core.policy import make_policy
from repro.launch import steps
from repro.models.api import build_model
from repro.optim import adamw

ARCHS = list_archs()
B, S = 2, 16


def _make(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    kw = {"max_positions": 64} if cfg.is_encoder_decoder else {}
    params = model.init(jax.random.PRNGKey(0), **kw)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["enc_frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model))
    if cfg.family == "vlm":
        batch["img_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (B, 4, cfg.d_model))
    return cfg, model, params, batch


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_config_limits(name):
    r = get_arch(name).reduced()
    assert r.n_layers <= 3
    assert r.d_model <= 512
    assert (r.n_experts or 0) <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finiteness(name):
    cfg, model, params, batch = _make(name)
    logits, aux = model.forward_train(params, batch)
    s_extra = 4 if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + s_extra, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    cfg, model, params, batch = _make(name)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    offset = 4 if cfg.family == "vlm" else 0
    train_step = steps.make_train_step(model, opt_cfg, label_offset=offset)
    opt_state = adamw.init(params)
    new_params, new_opt, metrics = jax.jit(train_step)(params, opt_state,
                                                       batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params))
    assert moved


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_roundtrip(name):
    cfg, model, params, batch = _make(name)
    pol = make_policy("lethe", capacity=16, sink_len=2, sparse_ratio=4.0)
    logits, state = model.prefill(params, batch, pol)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    start = S + (4 if cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(4):
        logits, state = model.decode_step(params, state, tok,
                                          jnp.asarray(start + t), pol)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all())
