"""Prefix-reuse battery (ISSUE 7).

Covers the guarantees DESIGN.md §Prefix-reuse promises:
  * differential — a full-prefix hit re-admits rows BIT-identical to
    recomputing the prefill, for lethe/h2o/streaming, bf16 and int8
    (the snapshot round-trip and the insert are both exact);
  * partial hits — suffix-only resumed prefill equals the whole-prompt
    prefill exactly on tokens and discrete cache state (zero q_tail
    refilled once the suffix covers the observation window; float
    payloads to split-extent tolerance), token-exactly through the
    scheduler in the non-compressed regime for pruning policies;
  * the host tier — TTL-then-LRU eviction under a bytes cap holds its
    invariants under fuzz (hypothesis + seeded fallback);
  * isolation — entries stored under one fingerprint (policy / kv_format /
    capacity / dtype / arch) can never hit a lookup under another;
  * the hash chain — digests are prefix-consistent at pow2-aligned
    boundaries and diverge on any token difference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import cache as cache_lib
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.prefix_cache import (PrefixCache, PrefixCacheConfig,
                                        chain_digests, prefix_fingerprint)
from repro.serving.scheduler import Request, Scheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


def _flat_equal(a, b, msg=""):
    """Bitwise pytree equality, leaf by leaf (path-labelled)."""
    fa, ta = jax.tree_util.tree_flatten_with_path(a)
    fb, tb = jax.tree_util.tree_flatten_with_path(b)
    assert ta == tb
    for (pa, la), (_, lb) in zip(fa, fb):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype, (msg, pa)
        np.testing.assert_array_equal(la, lb, err_msg=f"{msg} {pa}")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# Differential: full-prefix hits are bit-identical to recomputation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming"])
@pytest.mark.parametrize("kv_format", ["bf16", "int8"])
def test_full_hit_bit_identical_to_recompute(setup, kind, kv_format):
    """Admitting from the store == recomputing the prefill, down to the
    last bit of every cache leaf (K/V payloads, scales, RASR scores,
    budgets), for every pruning policy in both storage formats."""
    cfg, model, params = setup
    pol = make_policy(kind, capacity=24, sink_len=2, sparse_ratio=4.0,
                      kv_format=kv_format)
    eng = Engine(model, params, pol)
    batch = {"tokens": jnp.asarray(_prompt(cfg, 16, seed=3))[None, :]}

    logits, rows = eng.prefill_rows(batch)
    snap = cache_lib.extract_slots(rows, [0])

    # two identical fresh decode states; admit cold into one, from the
    # snapshot into the other — the states must be indistinguishable
    cold = cache_lib.insert_slots(eng.new_decode_state(2), [1], rows)
    logits2, rows2 = eng.prefill_rows(batch)   # the recomputation
    hit = cache_lib.insert_slots(eng.new_decode_state(2), [1], snap)
    _flat_equal(cold, hit, msg=f"{kind}/{kv_format}")
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))

    # and the decode trajectories stay identical
    first = int(np.asarray(jnp.argmax(logits, -1))[0])
    tokc = np.array([0, first], np.int32)
    pos = np.array([0, 16], np.int32)
    done = np.array([True, False])
    cold, segc, *_ = eng.decode_segment(cold, tokc, pos, done, 4)
    hit, segh, *_ = eng.decode_segment(hit, tokc, pos, done, 4)
    np.testing.assert_array_equal(np.asarray(segc)[1], np.asarray(segh)[1])


@pytest.mark.parametrize("kind", ["lethe", "streaming"])
def test_scheduler_full_hit_tokens_equal(setup, kind):
    """Through the scheduler: the second submission of an identical prompt
    is served from the store ("full") and generates the same tokens."""
    cfg, model, params = setup
    pol = make_policy(kind, capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    prompt = _prompt(cfg, 12, seed=5)
    reqs = [Request(uid=0, prompt=prompt.copy(), max_new_tokens=6),
            Request(uid=1, prompt=prompt.copy(), max_new_tokens=6)]
    pc = PrefixCache(PrefixCacheConfig(block_size=8))
    sched = Scheduler(eng, batch_slots=1, segment_len=4, prefix_cache=pc)
    sched.submit(reqs)
    done = sched.run()
    assert [c.prefix_hit for c in done] == ["miss", "full"]
    np.testing.assert_array_equal(done[0].tokens, done[1].tokens)
    s = sched.run_summary()
    assert s["prefix_full_hits"] == 1 and s["prefix_partial_hits"] == 0
    assert s["prefix_cache"]["inserts"] == 1


# --------------------------------------------------------------------------
# Partial hits: suffix-only resumed prefill
# --------------------------------------------------------------------------

def test_partial_hit_fullkv_matches_whole(setup):
    """FullKV partial hit == whole-prompt prefill: discrete cache state
    (positions, occupancy, budgets, eviction thresholds) and the greedy
    token exactly; float payloads to tight tolerance (the prefix rows were
    produced under a different pow2 length bucket, so XLA's reduction
    trees — and therefore the last mantissa bits — differ, exactly as the
    chunked-prefill battery documents for split-dependent extents). Once
    the suffix covers the observation window, the zero-seeded q_tail has
    fully refilled and resume carries no *algorithmic* approximation."""
    cfg, model, params = setup
    pol = make_policy("fullkv", capacity=64, obs_window=16)
    eng = Engine(model, params, pol)
    prefix = _prompt(cfg, 32, seed=7)
    suffix = _prompt(cfg, 16, seed=8)          # == obs_window
    whole = np.concatenate([prefix, suffix])

    _, prows = eng.prefill_rows({"tokens": jnp.asarray(prefix)[None, :]})
    snap = cache_lib.extract_slots(prows, [0])
    rlog, rrows = eng.resume_prefill_rows(
        snap, {"tokens": jnp.asarray(suffix)[None, :]},
        s_prefix=32, chunk_size=16)
    clog, crows = eng.prefill_rows({"tokens": jnp.asarray(whole)[None, :]},
                                   chunk_size=16)
    for name in ("pos", "length", "budget", "evict_at"):
        np.testing.assert_array_equal(
            np.asarray(getattr(crows, name)),
            np.asarray(getattr(rrows, name)), err_msg=name)
    for name in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(getattr(crows, name), np.float32),
            np.asarray(getattr(rrows, name), np.float32),
            rtol=2e-5, atol=2e-5, err_msg=name)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(clog, -1)),
                                  np.asarray(jnp.argmax(rlog, -1)))
    np.testing.assert_allclose(np.asarray(clog), np.asarray(rlog),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunked", [False, True])
def test_scheduler_partial_hit_matches_solo(setup, chunked):
    """Pruned-policy partial hit in the non-compressed regime (restored
    occupancy + suffix fits capacity): the resumed request's tokens equal
    a solo cold run's, in both admission modes."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=64, obs_window=16, sink_len=2)
    eng = Engine(model, params, pol)
    base = _prompt(cfg, 48, seed=11)
    ext = np.concatenate([base, _prompt(cfg, 16, seed=12)])

    solo = Scheduler(eng, batch_slots=1, segment_len=4)
    solo.submit([Request(uid=9, prompt=ext.copy(), max_new_tokens=5)])
    ref = solo.run()[0]

    pc = PrefixCache(PrefixCacheConfig(block_size=16))
    sched = Scheduler(eng, batch_slots=1, segment_len=4, prefix_cache=pc,
                      prefill_chunk_size=16 if chunked else None)
    sched.submit([Request(uid=0, prompt=base.copy(), max_new_tokens=5),
                  Request(uid=1, prompt=ext.copy(), max_new_tokens=5)])
    done = sched.run()
    assert done[1].prefix_hit == "partial"
    np.testing.assert_array_equal(done[1].tokens, ref.tokens)
    assert sched.run_summary()["prefix_partial_hits"] == 1


def test_partial_hit_nonpruning_overflow_falls_back_cold(setup):
    """A resume that would overflow capacity under a non-pruning policy
    raises the typed admission error; the scheduler falls back to a cold
    prefill (which then rejects or streams per the normal rules)."""
    cfg, model, params = setup
    pol = make_policy("fullkv", capacity=48, obs_window=16)
    eng = Engine(model, params, pol)
    base = _prompt(cfg, 32, seed=13)

    _, prows = eng.prefill_rows({"tokens": jnp.asarray(base)[None, :]})
    snap = cache_lib.extract_slots(prows, [0])
    with pytest.raises(ValueError, match="cannot evict"):
        eng.resume_prefill_rows(
            snap, {"tokens": jnp.asarray(_prompt(cfg, 32, seed=14))[None, :]},
            s_prefix=32, chunk_size=16)


# --------------------------------------------------------------------------
# Fingerprint isolation: incompatible entries never hit
# --------------------------------------------------------------------------

def test_fingerprint_mismatch_never_hits(setup):
    """Entries stored under one engine identity are invisible to every
    other: policy kind, capacity, kv_format, cache dtype and arch all
    fold into the chain seed."""
    cfg, _, _ = setup
    toks = _prompt(cfg, 32, seed=21)
    rows = {"k": np.zeros((2, 1, 4), np.int8)}
    base_pol = make_policy("lethe", capacity=64)
    fp = prefix_fingerprint(base_pol, jnp.bfloat16, arch="a")

    pc = PrefixCache(PrefixCacheConfig(block_size=16))
    assert pc.insert(fp, toks, rows, first_token=1)
    assert pc.lookup(fp, toks) is not None

    others = [
        prefix_fingerprint(make_policy("h2o", capacity=64),
                           jnp.bfloat16, arch="a"),
        prefix_fingerprint(make_policy("lethe", capacity=32),
                           jnp.bfloat16, arch="a"),
        prefix_fingerprint(make_policy("lethe", capacity=64,
                                       kv_format="int8"),
                           jnp.bfloat16, arch="a"),
        prefix_fingerprint(base_pol, jnp.float32, arch="a"),
        prefix_fingerprint(base_pol, jnp.bfloat16, arch="b"),
    ]
    assert len({fp, *others}) == len(others) + 1    # all distinct
    for other in others:
        assert pc.lookup(other, toks) is None


def test_mesh_topology_folds_into_fingerprint(setup):
    """Snapshots captured under one serving-mesh topology are invisible to
    every other (and to single-device serving): the per-shard byte layout
    differs, so the topology token seeds the hash chain too."""
    cfg, _, _ = setup
    toks = _prompt(cfg, 32, seed=23)
    pol = make_policy("lethe", capacity=64)
    fp_single = prefix_fingerprint(pol, jnp.bfloat16, arch="a")
    fp_m22 = prefix_fingerprint(pol, jnp.bfloat16, arch="a",
                                mesh="mesh(data=2,model=2)")
    fp_m14 = prefix_fingerprint(pol, jnp.bfloat16, arch="a",
                                mesh="mesh(data=1,model=4)")
    assert len({fp_single, fp_m22, fp_m14}) == 3

    pc = PrefixCache(PrefixCacheConfig(block_size=16))
    rows = {"k": np.zeros((2, 1, 4), np.int8)}
    assert pc.insert(fp_m22, toks, rows, first_token=1)
    assert pc.lookup(fp_m22, toks) is not None
    assert pc.lookup(fp_single, toks) is None
    assert pc.lookup(fp_m14, toks) is None


# --------------------------------------------------------------------------
# Hash chain: prefix consistency at pow2-aligned boundaries
# --------------------------------------------------------------------------

def test_chain_digests_prefix_consistent():
    """Prompts sharing their first b tokens share the digest at every
    pow2-aligned boundary <= b; one differing token diverges everything
    at and after its boundary."""
    fp = b"\x01" * 16
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1000, size=96).astype(np.int32)
    b = a.copy()
    b[64:] = rng.integers(0, 1000, size=32)

    def digests(toks):
        from repro.serving.engine import chunk_plan
        bounds = tuple(int(x) for x in np.cumsum(chunk_plan(len(toks), 32)))
        return dict(chain_digests(fp, toks, bounds))

    da, db = digests(a), digests(b)
    assert da[32] == db[32] and da[64] == db[64]
    assert da[96] != db[96]

    c = a.copy()
    c[0] += 1                                      # first chunk differs
    dc = digests(c)
    assert all(da[k] != dc[k] for k in da)

    # a stored full prompt is findable from its extension's boundary
    pc = PrefixCache(PrefixCacheConfig(block_size=32))
    pc.insert(fp, a[:64], {"x": np.zeros(4, np.float32)}, first_token=0)
    hit = pc.lookup(fp, a)                          # a extends a[:64]
    assert hit is not None and not hit.full and hit.prefix_len == 64
    hit2 = pc.lookup(fp, a[:64])
    assert hit2 is not None and hit2.full
    assert pc.lookup(fp, b[:48]) is None            # unaligned prefix


# --------------------------------------------------------------------------
# Host tier: TTL-then-LRU under a bytes cap (fuzz + seeded fallback)
# --------------------------------------------------------------------------

def _mk_rows(nbytes):
    return {"k": np.zeros(max(nbytes, 1), np.uint8)}


def _tier_case(ops):
    """ops: list of (kind, arg) — drive a small capped store through
    insert/lookup/advance and check every invariant after each op."""
    clock = FakeClock()
    cfg = PrefixCacheConfig(max_bytes=4096, block_size=8, base_ttl_s=100.0,
                            min_ttl_s=10.0, max_ttl_s=1000.0, min_tokens=2)
    pc = PrefixCache(cfg, clock=clock)
    fp = b"\x02" * 16
    rng = np.random.default_rng(42)
    prompts = {i: rng.integers(0, 100, size=8 * (1 + i % 3)).astype(np.int32)
               for i in range(8)}
    for kind, arg in ops:
        if kind == "insert":
            pc.insert(fp, prompts[arg % 8], _mk_rows(512 * (1 + arg % 4)),
                      first_token=arg)
        elif kind == "lookup":
            hit = pc.lookup(fp, prompts[arg % 8])
            if hit is not None:
                assert not hit.entry.expired(clock.t)
        else:                                       # advance the clock
            clock.t += float(arg)
        # invariants, after every operation
        assert pc.bytes_used == sum(e.nbytes for e in pc._entries.values())
        assert pc.bytes_used <= cfg.max_bytes
        for e in pc._entries.values():
            assert cfg.min_ttl_s <= e.ttl_s <= cfg.max_ttl_s
    s = pc.stats()
    assert s["lookups"] == s["full_hits"] + s["partial_hits"] + s["misses"]
    assert s["entries"] == len(pc)
    assert (s["inserts"] - s["evictions_ttl"] - s["evictions_lru"]
            == s["entries"])


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    _OP = st.one_of(
        st.tuples(st.just("insert"), st.integers(0, 7)),
        st.tuples(st.just("lookup"), st.integers(0, 7)),
        st.tuples(st.just("tick"), st.integers(1, 400)))

    @settings(max_examples=40, deadline=None)
    @given(st.lists(_OP, min_size=1, max_size=40))
    def test_fuzz_tier_invariants(ops):
        _tier_case(ops)
except ImportError:                              # pragma: no cover
    pass                                         # seeded sweep below


@pytest.mark.parametrize("seed", range(6))
def test_seeded_tier_invariants(seed):
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(rng.integers(5, 40)):
        k = rng.integers(0, 3)
        ops.append([("insert", int(rng.integers(0, 8))),
                    ("lookup", int(rng.integers(0, 8))),
                    ("tick", int(rng.integers(1, 400)))][k])
    _tier_case(ops)


def test_ttl_expiry_and_lru_order():
    """Deterministic tier scenario: expiry removes stale entries on probe,
    and byte pressure evicts in strict least-recently-used order."""
    clock = FakeClock()
    cfg = PrefixCacheConfig(max_bytes=3000, block_size=8, base_ttl_s=100.0,
                            min_ttl_s=10.0, max_ttl_s=1000.0, min_tokens=2)
    pc = PrefixCache(cfg, clock=clock)
    fp = b"\x03" * 16
    rng = np.random.default_rng(1)
    p = {i: rng.integers(0, 100, size=8).astype(np.int32) + 100 * i
         for i in range(4)}

    assert pc.insert(fp, p[0], _mk_rows(1000), first_token=0)
    clock.t = 50.0
    assert pc.lookup(fp, p[0]) is not None          # refreshes recency and
    #                                                 boosts TTL to ~134.7s
    assert pc.insert(fp, p[1], _mk_rows(1000), first_token=1)

    clock.t = 160.0              # p1 stale (110s > its 100s base TTL);
    #                              p0's boosted TTL still covers the gap
    assert pc.lookup(fp, p[1]) is None
    assert pc.stats()["evictions_ttl"] == 1
    assert pc.lookup(fp, p[0]) is not None

    # fill to the cap, then overflow: LRU (p2, untouched) goes first
    clock.t = 170.0
    assert pc.insert(fp, p[2], _mk_rows(1000), first_token=2)
    clock.t = 175.0
    assert pc.lookup(fp, p[0]) is not None           # p0 most recent
    assert pc.insert(fp, p[3], _mk_rows(2000), first_token=3)
    assert pc.lookup(fp, p[2]) is None               # LRU victim
    assert pc.lookup(fp, p[0]) is not None
    assert pc.stats()["evictions_lru"] >= 1


def test_store_skips_trivial_and_oversized():
    pc = PrefixCache(PrefixCacheConfig(max_bytes=100, min_tokens=4))
    fp = b"\x04" * 16
    assert not pc.insert(fp, np.arange(2, dtype=np.int32),
                         _mk_rows(10), first_token=0)
    assert not pc.insert(fp, np.arange(8, dtype=np.int32),
                         _mk_rows(500), first_token=0)
    assert pc.stats()["too_large"] == 1
    assert len(pc) == 0
