"""models/shard_hints unit tests: the hint() degradation contract and the
REPRO_PREFILL_SEQ_SHARD=1 context-parallel prefill layout.

The first two tests run on any host (no devices needed); the mesh-backed
spec check skips below 2 devices (the CI ``sharded`` job runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import shard_hints


def test_hint_is_noop_outside_mesh():
    """with_sharding_constraint against unbound axis names must degrade to
    identity — prefill runs unchanged on a mesh-less host."""
    x = jnp.arange(12.0).reshape(3, 4)
    y = shard_hints.hint(x, "data", "model")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    # and through jit, where the constraint would otherwise be staged
    def f(x):
        return shard_hints.hint(x, "data", None) * 2.0
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)),
                                  np.asarray(x) * 2.0)


def test_seq_shard_disabled_is_identity(monkeypatch):
    monkeypatch.delenv("REPRO_PREFILL_SEQ_SHARD", raising=False)
    q = jnp.zeros((1, 2, 4, 8))
    k = jnp.ones((1, 1, 4, 8))
    q2, k2, v2 = shard_hints.prefill_attention_hints(q, k, k)
    assert q2 is q and k2 is k and v2 is k
    out = jnp.zeros((1, 2, 4, 8))
    assert shard_hints.prefill_out_hint(out) is out


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_seq_shard_specs_under_host_mesh(monkeypatch):
    """REPRO_PREFILL_SEQ_SHARD=1 under a (data, model) mesh produces the
    documented layout: Q and the attention output sequence-sharded on
    'model', K/V replicated across 'model'."""
    monkeypatch.setenv("REPRO_PREFILL_SEQ_SHARD", "1")
    mesh = Mesh(np.array(jax.devices()[:2]).reshape(1, 2),
                ("data", "model"))
    qh = jnp.zeros((2, 4, 8, 16))              # [B, Hq, S, Dh]
    kh = jnp.zeros((2, 2, 8, 16))              # [B, Hkv, S, Dh]

    with mesh:
        q2, k2, v2 = jax.jit(shard_hints.prefill_attention_hints)(
            qh, kh, kh)
        out = jax.jit(shard_hints.prefill_out_hint)(qh)

    def same(x, spec):
        return x.sharding.is_equivalent_to(
            NamedSharding(mesh, spec), x.ndim)
    assert same(q2, P("data", None, "model", None))
    assert same(out, P("data", None, "model", None))
    assert same(k2, P("data", None, None, None))
    assert same(v2, P("data", None, None, None))
