"""Sharding-rule unit tests (pure spec logic — no devices needed) plus one
subprocess-based small-mesh lower+compile integration check."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.launch import shardings, specs
from repro.models.api import build_model


class FakeMesh:
    """Duck-typed mesh for spec-logic tests (no jax devices)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        return int(np.prod(list(self.shape.values())))


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _params_sds(name, shape_name="decode_32k"):
    cfg = get_arch(name)
    model = build_model(cfg)
    from repro.configs import get_shape
    return cfg, model, specs.params_sds(model, get_shape(shape_name))


def _flat(tree):
    return {"/".join(str(getattr(p, "key", getattr(p, "idx", "?")))
                     for p in path): leaf
            for path, leaf in
            jax.tree_util.tree_flatten_with_path(tree)[0]}


def test_divisibility_everywhere():
    """Every spec must exactly divide its tensor on the production mesh —
    the invariant jit in_shardings enforce. Checked for all 10 archs,
    params + decode state + batch."""
    from repro.configs import SHAPES, get_shape, list_archs
    sizes = {"data": 16, "model": 16, "pod": 2}
    for name in list_archs():
        cfg = get_arch(name)
        model = build_model(cfg)
        for shape_name in SHAPES:
            shape = get_shape(shape_name)
            case = specs.case_for(cfg, shape)
            if case.skip_reason:
                continue
            p_sds = specs.params_sds(model, shape)
            p_spec = shardings.param_specs(p_sds, cfg, MESH)
            trees = [(p_sds, p_spec)]
            if shape.kind == "decode":
                st = specs.decode_state_sds(model, shape, case.policy)
                trees.append((st, shardings.state_specs(
                    st, cfg, MESH, shape.global_batch)))
            for sds_tree, spec_tree in trees:
                flat_s = _flat(sds_tree)
                flat_p = _flat(spec_tree)
                for k, leaf in flat_s.items():
                    spec = flat_p[k]
                    for ax, names in enumerate(spec):
                        if names is None:
                            continue
                        ns = (names,) if isinstance(names, str) else names
                        div = int(np.prod([sizes[n] for n in ns]))
                        assert leaf.shape[ax] % div == 0, \
                            (name, shape_name, k, leaf.shape, spec)


def test_expert_parallel_when_divisible():
    cfg, model, p_sds = _params_sds("arctic-480b")
    spec = shardings.param_specs(p_sds, cfg, MESH)
    moe_up = spec["layers"]["moe"]["w_up"]     # [L, E, D, F]
    assert moe_up == P(None, "model", None, None)   # 128 experts / 16


def test_tensor_parallel_fallback_small_expert_count():
    cfg, model, p_sds = _params_sds("mixtral-8x7b")
    spec = shardings.param_specs(p_sds, cfg, MESH)
    moe_up = spec["layers"]["moe"]["w_up"]     # [L, 8, D, F]: 8 < 16
    assert moe_up == P(None, None, None, "model")   # falls back to F


def test_kv_cache_fallback_chain():
    # gemma2 kv=16 -> heads sharded; qwen2.5 kv=8 -> capacity sharded
    for name, expect_axis in [("gemma2-27b", 2), ("qwen2.5-32b", 3)]:
        cfg = get_arch(name)
        model = build_model(cfg)
        from repro.configs import get_shape
        shape = get_shape("decode_32k")
        pol = make_policy("lethe", capacity=4096)
        st = specs.decode_state_sds(model, shape, pol)
        spec = shardings.state_specs(st, cfg, MESH, shape.global_batch)
        kspec = spec.k if not isinstance(spec, dict) else spec["kv"].k
        assert kspec[expect_axis] == "model", (name, kspec)
        assert kspec[1] == "data"


def test_kv_priority_rejects_unknown_token(monkeypatch):
    """A typo in REPRO_KV_SHARD_PRIORITY must fail loudly, naming the
    valid tokens — not silently fall back to the default order."""
    monkeypatch.setenv("REPRO_KV_SHARD_PRIORITY", "heads,bogus")
    with pytest.raises(ValueError, match=r"'heads', 'cap', 'dh'"):
        shardings._kv_priority()
    with pytest.raises(ValueError, match=r"invalid token ''"):
        monkeypatch.setenv("REPRO_KV_SHARD_PRIORITY", "heads,,dh")
        shardings._kv_priority()
    # whitespace around tokens is tolerated
    monkeypatch.setenv("REPRO_KV_SHARD_PRIORITY", "heads , dh")
    assert shardings._kv_priority() == (0, 2)


def test_serving_cache_specs_keep_capacity_local():
    """serving=True: the model axis follows the priority chain with 'cap'
    removed — C stays shard-local even when the env order prefers it."""
    import os
    cfg = get_arch("qwen2.5-32b")
    model = build_model(cfg)
    from repro.configs import get_shape
    shape = get_shape("decode_32k")
    pol = make_policy("lethe", capacity=4096)
    st = specs.decode_state_sds(model, shape, pol)
    old = os.environ.get("REPRO_KV_SHARD_PRIORITY")
    os.environ["REPRO_KV_SHARD_PRIORITY"] = "cap,heads,dh"
    try:
        spec = shardings.state_specs(st, cfg, MESH, shape.global_batch,
                                     serving=True)
    finally:
        if old is None:
            del os.environ["REPRO_KV_SHARD_PRIORITY"]
        else:
            os.environ["REPRO_KV_SHARD_PRIORITY"] = old
    kspec = spec.k if not isinstance(spec, dict) else spec["kv"].k
    assert kspec[3] is None                      # C never sharded
    assert "model" not in (kspec[3],)
    sspec = spec.score if not isinstance(spec, dict) else spec["kv"].score
    assert sspec[2] is None                      # score's C axis local too


def test_long500k_sequence_parallel():
    cfg = get_arch("qwen2.5-32b")
    model = build_model(cfg)
    from repro.configs import get_shape
    shape = get_shape("long_500k")
    pol = make_policy("lethe", capacity=specs.LETHE_CAP_LONG)
    st = specs.decode_state_sds(model, shape, pol)
    spec = shardings.state_specs(st, cfg, MESH, 1)
    assert spec.k[3] == ("data", "model")     # capacity over all axes
    assert spec.k[1] is None                  # B=1: no data sharding


def test_whisper_vocab_fallback():
    cfg, model, p_sds = _params_sds("whisper-large-v3")
    spec = shardings.param_specs(p_sds, cfg, MESH)
    # 51866 % 16 != 0 -> falls back to the d_model axis
    assert spec["embed"] == P(None, "model")


@pytest.mark.slow
def test_small_mesh_lower_compile_subprocess():
    """A real lower+compile on 8 fake devices via the dryrun module path."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch, get_shape
from repro.kernels import ops as kops
kops.set_default_impl("ref")
from repro.launch import shardings, specs, steps
from repro.models.api import build_model

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)
cfg = dataclasses.replace(
    get_arch("qwen2.5-32b"), n_layers=2, d_model=256, n_heads=8,
    n_kv_heads=4, d_head=32, d_ff=512, vocab_size=1024)
shape = dataclasses.replace(get_shape("decode_32k"), seq_len=256,
                            global_batch=4)
model = build_model(cfg)
pol = specs.make_policy("lethe", capacity=128)
p_sds = specs.params_sds(model, shape)
p_sh = shardings.to_named(shardings.param_specs(p_sds, cfg, mesh), mesh)
st_sds = specs.decode_state_sds(model, shape, pol)
st_sh = shardings.to_named(
    shardings.state_specs(st_sds, cfg, mesh, 4), mesh)
tok_sds, pos_sds = specs.decode_inputs_sds(shape)
fn = steps.make_serve_step(model, pol)
jfn = jax.jit(fn, in_shardings=(
    p_sh, st_sh, NamedSharding(mesh, shardings.token_spec(mesh, 4)),
    NamedSharding(mesh, P())))
with mesh:
    compiled = jfn.lower(p_sds, st_sds, tok_sds, pos_sds).compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):   # pre-0.5 jax returns a per-device list
    ca = ca[0]
assert ca.get("flops", 0) > 0
print("COMPILE_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "COMPILE_OK" in r.stdout, r.stderr[-2000:]
