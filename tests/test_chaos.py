"""Chaos / fault-isolation battery.

Injected NaN logits, mid-segment row faults, over-capacity prompts, and
policy-inadmissible prompts must each terminate exactly ONE request with
the right typed reason while every surviving request's tokens stay
bit-identical to a fault-free run of the same traffic. The guarded decode
segment runs the SAME compiled program with and without chaos, so survivor
identity is structural, not statistical — these tests pin it anyway.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, ChaosConfig,
                                     FrontDoorCore, ServeRequest)

pytestmark = pytest.mark.chaos

INF = float("inf")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    return cfg, model, params, eng


def _reqs(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(uid=i,
                         prompt=rng.integers(0, cfg.vocab_size,
                                             size=s).astype(np.int32),
                         max_new_tokens=n)
            for i, (s, n) in enumerate(spec)]


def _transparent():
    return AdmissionConfig(compress_at=INF, shed_at=INF, reject_at=INF)


def _run(eng, reqs, *, slots, chaos=None):
    core = FrontDoorCore(eng, batch_slots=slots, segment_len=4,
                         admission=_transparent(), chaos=chaos)
    core.submit(reqs)
    return {c.uid: c for c in core.run()}, core.run_summary()


@pytest.mark.parametrize("field,detail", [("nan_logits_at", "nan_logits"),
                                          ("fault_at", "row_fault")])
def test_injected_fault_kills_exactly_one_request(setup, field, detail):
    """A fault at generated-token index k terminates only the poisoned
    request (typed ``failed`` + failure_detail) after exactly k clean
    tokens; every survivor is bit-identical to the fault-free run."""
    cfg, model, params, eng = setup
    reqs = _reqs(cfg, [(8, 10), (10, 10), (12, 10)], seed=0)
    clean, clean_sum = _run(eng, reqs, slots=3)
    assert clean_sum["failed"] == 0
    assert clean_sum["failure_details"] == {}
    assert all(c.failure_detail is None for c in clean.values())

    k = 5
    chaos = ChaosConfig(**{field: {1: k}})
    faulted, s = _run(eng, reqs, slots=3, chaos=chaos)

    assert faulted[1].finish_reason == "failed", detail
    assert faulted[1].failure_detail == detail    # typed taxonomy
    assert s["failure_details"] == {detail: 1}
    assert len(faulted[1].tokens) == k            # clean prefix preserved
    np.testing.assert_array_equal(faulted[1].tokens,
                                  clean[1].tokens[:k])
    assert s["failed"] == 1 and s["completed"] == 3
    for uid in (0, 2):                            # survivors untouched
        assert faulted[uid].finish_reason == clean[uid].finish_reason
        np.testing.assert_array_equal(faulted[uid].tokens,
                                      clean[uid].tokens,
                                      err_msg=f"survivor uid {uid}")


def test_fault_mid_refill_wave(setup):
    """The fault fires on a request admitted AFTER others already finished
    and recycled slots — isolation must hold across refill churn too."""
    cfg, model, params, eng = setup
    reqs = _reqs(cfg, [(8, 3), (10, 12), (8, 4), (10, 9)], seed=1)
    clean, _ = _run(eng, reqs, slots=2)
    faulted, s = _run(eng, reqs, slots=2,
                      chaos=ChaosConfig(nan_logits_at={3: 4}))
    assert faulted[3].finish_reason == "failed"
    assert faulted[3].failure_detail == "nan_logits"
    assert len(faulted[3].tokens) == 4
    assert s["failed"] == 1 and s["completed"] == 4
    for uid in (0, 1, 2):
        np.testing.assert_array_equal(faulted[uid].tokens,
                                      clean[uid].tokens,
                                      err_msg=f"survivor uid {uid}")


def test_over_capacity_prompt_rejected_neighbors_clean(setup):
    cfg, model, params, eng = setup
    ok = _reqs(cfg, [(8, 6), (10, 6)], seed=2)
    huge = ServeRequest(uid=9, prompt=np.zeros(64, np.int32),
                        max_new_tokens=4)
    clean, _ = _run(eng, ok, slots=2)
    mixed, s = _run(eng, [ok[0], huge, ok[1]], slots=2)
    assert mixed[9].finish_reason == "rejected"
    assert len(mixed[9].tokens) == 0
    assert s["rejected"] == 1 and s["completed"] == 3
    for r in ok:
        np.testing.assert_array_equal(mixed[r.uid].tokens,
                                      clean[r.uid].tokens)


def test_policy_inadmissible_prompt_rejected(setup):
    """FullKV cannot admit a prompt longer than capacity: the group is
    rejected with the typed reason instead of poisoning the pool, and
    short requests still serve."""
    cfg, model, params, _ = setup
    pol = make_policy("fullkv", capacity=16)
    eng = Engine(model, params, pol)
    rng = np.random.default_rng(3)
    long = ServeRequest(uid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=20).astype(np.int32), max_new_tokens=4)
    short = ServeRequest(uid=1, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4)
    done, s = _run(eng, [long, short], slots=1)
    assert done[0].finish_reason == "rejected"
    assert done[1].finish_reason in ("length", "eos")
    assert s["rejected"] == 1 and s["completed"] == 2


def test_chaos_run_drains_and_slots_recycle(setup):
    """After a fault the slot must come back into rotation: later queued
    work decodes in the recycled slot and the door fully drains."""
    cfg, model, params, eng = setup
    reqs = _reqs(cfg, [(8, 12), (10, 12), (8, 6), (10, 6)], seed=4)
    done, s = _run(eng, reqs, slots=2,
                   chaos=ChaosConfig(fault_at={0: 3}))
    assert done[0].finish_reason == "failed"
    assert s["completed"] == 4
    for uid in (2, 3):                 # admitted after the fault
        assert done[uid].finish_reason in ("length", "eos")
        assert len(done[uid].tokens) == 6
