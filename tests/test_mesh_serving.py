"""Mesh-sharded serving battery (DESIGN.md §Sharded serving).

Proves the tensor-parallel serving path is a *transparent* layout change:

  * differential — mesh decode produces exactly the single-device tokens
    (and cache leaves equal to documented float tolerance) for
    lethe/h2o/streaming, bf16 and int8, through the slot primitives the
    scheduler composes;
  * placement — the live decode state lands where
    ``shardings.state_specs(serving=True)`` says: kv-heads on ``model``,
    slots on ``data``, the capacity axis C always shard-local;
  * the shard_map decode kernel (partial-softmax psum epilogue) matches
    the jnp oracle, at the ops level and through the engine's jitted
    ``decode_segment``;
  * indivisible head counts fall back to the GSPMD-partitioned oracle and
    still match;
  * the serving stack on top keeps working: scheduler differential,
    preempt→resume round trip, prefix-store full hit — all under the mesh.

The whole module skips on a single-device host: run it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI ``sharded``
job does).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.core import cache as cache_lib
from repro.core.policy import make_policy
from repro.kernels import ops, ref
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, FrontDoorCore,
                                     ServeRequest)
from repro.serving.meshing import ServingMesh, parse_mesh_arg
from repro.serving.prefix_cache import PrefixCache, PrefixCacheConfig
from repro.serving.scheduler import Request, Scheduler

NEED = 4
pytestmark = pytest.mark.skipif(
    jax.device_count() < NEED,
    reason=f"mesh battery needs >= {NEED} devices; run under "
           f"XLA_FLAGS=--xla_force_host_platform_device_count=8")

INF = float("inf")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()     # Hq=4, Hkv=2, Dh=32
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)


def _leaves_close(a, b, msg=""):
    """Mesh state == single-device state, leaf by leaf. Documented
    tolerance: float leaves to allclose(rtol=1e-4, atol=5e-5) — GSPMD
    partitioning reassociates reductions, so f32 payloads/scores/scales
    carry ~1e-6-relative jitter; int8 payloads to one quantisation step
    (the jitter may flip a rounded code by one); every other integer leaf
    (positions, lengths, budgets, eviction state) bit-exact."""
    fa, ta = jax.tree_util.tree_flatten_with_path(a)
    fb, tb = jax.tree_util.tree_flatten_with_path(b)
    assert ta == tb
    for (pa, la), (_, lb) in zip(fa, fb):
        la, lb = np.asarray(la), np.asarray(lb)
        path = f"{msg} {jax.tree_util.keystr(pa)}"
        assert la.dtype == lb.dtype, path
        if la.dtype == np.int8:
            d = np.abs(la.astype(np.int32) - lb.astype(np.int32)).max()
            assert d <= 1, f"{path}: int8 codes differ by {d} > 1"
        elif np.issubdtype(la.dtype, np.floating):
            np.testing.assert_allclose(
                la.astype(np.float64), lb.astype(np.float64),
                rtol=1e-4, atol=5e-5, err_msg=path)
        else:
            np.testing.assert_array_equal(la, lb, err_msg=path)


def _transparent(**kw):
    base = dict(compress_at=INF, shed_at=INF, reject_at=INF)
    base.update(kw)
    return AdmissionConfig(**base)


def _solo(engine, prompt, max_new):
    res = engine.generate({"tokens": jnp.asarray(prompt)[None, :]}, max_new)
    return np.asarray(res.tokens[0, :res.gen_lens[0]])


# --------------------------------------------------------------------------
# Placement: the live state lands on the serving layout
# --------------------------------------------------------------------------

def test_serving_state_placement(setup):
    """kv-heads on 'model', slots on 'data', C shard-local — and every
    leaf of the fresh state matches state_specs(serving=True) exactly."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0,
                      kv_format="int8")
    eng = Engine(model, params, pol, mesh=ServingMesh.build((2, 2)))
    state = eng.new_decode_state(4)

    caches = [x for x in jax.tree.leaves(
        state, is_leaf=lambda t: isinstance(t, cache_lib.KVCache))
        if isinstance(x, cache_lib.KVCache)]
    assert caches
    c = caches[0]                                # k [L, B, Hkv, C, Dh]
    assert c.k.sharding.spec[2] == "model"       # heads split 2-way
    assert c.k.sharding.spec[3] is None          # C never sharded
    assert c.k.sharding.spec[1] == "data"        # slots split 2-way
    assert c.k_scale.sharding.spec[2] == "model"  # scales co-shard
    assert c.length.sharding.spec[1] == "data"
    assert c.pos.sharding.spec[2] is None        # C local on metadata too

    from repro.launch import shardings
    spec_tree = shardings.state_specs(state, cfg, eng.mesh.mesh, 4,
                                      serving=True)
    flat_s = jax.tree_util.tree_flatten_with_path(state)[0]
    flat_p = jax.tree_util.tree_flatten_with_path(spec_tree)[0]
    for (path, leaf), (_, spec) in zip(flat_s, flat_p):
        want = NamedSharding(eng.mesh.mesh, spec)
        assert leaf.sharding.is_equivalent_to(want, leaf.ndim), \
            (jax.tree_util.keystr(path), leaf.sharding, spec)

    # params went through the production rules: something is model-sharded
    assert any("model" in str(leaf.sharding.spec)
               for leaf in jax.tree.leaves(eng.params))


def test_mesh_build_errors():
    with pytest.raises(ValueError, match="two comma-separated ints"):
        parse_mesh_arg("2x4")
    with pytest.raises(ValueError, match=">= 1"):
        parse_mesh_arg("0,4")
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        ServingMesh.build((64, 64))


# --------------------------------------------------------------------------
# Differential: mesh slot decode == single-device slot decode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["lethe", "h2o", "streaming"])
@pytest.mark.parametrize("kv_format", ["bf16", "int8"])
def test_mesh_decode_differential(setup, kind, kv_format):
    """(data=2, model=2): admit + segment decode through the slot
    primitives must reproduce the single-device tokens exactly and the
    cache leaves to the documented tolerance."""
    cfg, model, params = setup
    pol = make_policy(kind, capacity=24, sink_len=2, sparse_ratio=4.0,
                      kv_format=kv_format)
    eng0 = Engine(model, params, pol)
    eng1 = Engine(model, params, pol, mesh=ServingMesh.build((2, 2)))
    batch = {"tokens": jnp.asarray(_prompts(cfg, (4, 12), seed=1))}

    s0 = eng0.new_decode_state(4)
    s1 = eng1.new_decode_state(4)
    s0, first0 = eng0.admit_slots(s0, [0, 1, 2, 3], batch)
    s1, first1 = eng1.admit_slots(s1, [0, 1, 2, 3], batch)
    np.testing.assert_array_equal(np.asarray(first0), np.asarray(first1),
                                  err_msg=f"{kind}/{kv_format} first")

    tok = np.asarray(first0)
    pos = np.full(4, 12, np.int32)
    done = np.zeros(4, bool)
    s0, seg0, *_ = eng0.decode_segment(s0, tok, pos, done, 6)
    s1, seg1, *_ = eng1.decode_segment(s1, tok, pos, done, 6)
    np.testing.assert_array_equal(np.asarray(seg0), np.asarray(seg1),
                                  err_msg=f"{kind}/{kv_format} segment")
    _leaves_close(s0, s1, msg=f"{kind}/{kv_format}")


def test_indivisible_heads_fall_back_to_oracle(setup):
    """(data=1, model=4) does not divide Hkv=2: decode must take the
    GSPMD-partitioned jnp-oracle path and still match single-device."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng0 = Engine(model, params, pol)
    eng1 = Engine(model, params, pol, mesh=ServingMesh.build((1, 4)))
    batch = {"tokens": jnp.asarray(_prompts(cfg, (2, 10), seed=2))}

    s0 = eng0.new_decode_state(2)
    s1 = eng1.new_decode_state(2)
    s0, first0 = eng0.admit_slots(s0, [0, 1], batch)
    s1, first1 = eng1.admit_slots(s1, [0, 1], batch)
    np.testing.assert_array_equal(np.asarray(first0), np.asarray(first1))
    tok, pos, done = np.asarray(first0), np.full(2, 10, np.int32), \
        np.zeros(2, bool)
    s0, seg0, *_ = eng0.decode_segment(s0, tok, pos, done, 8)
    s1, seg1, *_ = eng1.decode_segment(s1, tok, pos, done, 8)
    np.testing.assert_array_equal(np.asarray(seg0), np.asarray(seg1))
    _leaves_close(s0, s1, msg="tp4-fallback")


# --------------------------------------------------------------------------
# shard_map decode kernel: psum epilogue == oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [False, True])
def test_shard_map_kernel_matches_oracle(quant):
    """ops.decode_attention_fused under an active mesh dispatches the
    shard_map-wrapped Pallas kernel (interpret on CPU); its output,
    psum'd probsum and EMA'd scores must match the no-mesh oracle."""
    B, Hq, Hkv, C, Dh = 4, 4, 2, 64, 32
    lives = [1, 17, 33, 64]
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    kf = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    vf = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.stack([jnp.where(jnp.arange(C) < n, jnp.arange(C), -1)
                     for n in lives]).astype(jnp.int32)
    score = jnp.where(pos >= 0, jax.random.uniform(ks[3], (B, C)), 0.0)
    cur = jnp.asarray([n - 1 for n in lives], jnp.int32)
    k_scale = v_scale = None
    k, v = kf, vf
    if quant:
        amax_k = jnp.abs(kf).max(-1) / 127.0            # [B,Hkv,C]
        amax_v = jnp.abs(vf).max(-1) / 127.0
        k = jnp.round(kf / amax_k[..., None]).astype(jnp.int8)
        v = jnp.round(vf / amax_v[..., None]).astype(jnp.int8)
        k_scale, v_scale = amax_k, amax_v

    o_ref, ps_ref, ns_ref = ref.decode_attention_fused_ref(
        q, k, v, pos, cur, score, gamma=0.95, window=None,
        scale=Dh ** -0.5, k_scale=k_scale, v_scale=v_scale)

    sm = ServingMesh.build((2, 2))
    with sm.mesh:
        o, ps, ns = ops.decode_attention_fused(
            q, k, v, pos, cur, score, gamma=0.95, scale=Dh ** -0.5,
            k_scale=k_scale, v_scale=v_scale, impl="interpret")
    assert np.abs(np.asarray(o) - np.asarray(o_ref)).max() <= 1e-5
    assert np.abs(np.asarray(ps) - np.asarray(ps_ref)).max() <= 1e-5
    assert np.abs(np.asarray(ns) - np.asarray(ns_ref)).max() <= 1e-5


@pytest.mark.parametrize("kv_format", ["bf16", "int8"])
def test_engine_decode_via_shard_map_kernel(setup, kv_format):
    """Force impl=interpret so the (2,2)-mesh engine dispatches the
    shard_map kernel inside its jitted decode_segment — tokens must still
    match the single-device engine running the plain interpret kernel."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=16, sink_len=2, sparse_ratio=4.0,
                      kv_format=kv_format)
    eng0 = Engine(model, params, pol)
    eng1 = Engine(model, params, pol, mesh=ServingMesh.build((2, 2)))
    batch = {"tokens": jnp.asarray(_prompts(cfg, (2, 8), seed=7))}
    s0 = eng0.new_decode_state(2)
    s1 = eng1.new_decode_state(2)
    s0, first0 = eng0.admit_slots(s0, [0, 1], batch)
    s1, first1 = eng1.admit_slots(s1, [0, 1], batch)
    tok = np.asarray(first0)
    pos, done = np.full(2, 8, np.int32), np.zeros(2, bool)
    # interpret only around decode: the Pallas *prefill* kernel cannot take
    # a traced window, and prefill is not what this test is about
    ops.set_default_impl("interpret")
    jax.clear_caches()
    try:
        s0, seg0, *_ = eng0.decode_segment(s0, tok, pos, done, 5)
        s1, seg1, *_ = eng1.decode_segment(s1, tok, pos, done, 5)
    finally:
        ops.set_default_impl("auto")
        jax.clear_caches()
    np.testing.assert_array_equal(np.asarray(first0), np.asarray(first1))
    np.testing.assert_array_equal(np.asarray(seg0), np.asarray(seg1))
    _leaves_close(s0, s1, msg=f"shard_map/{kv_format}")


# --------------------------------------------------------------------------
# The serving stack on top: scheduler, preemption, prefix store
# --------------------------------------------------------------------------

def test_scheduler_matches_solo_under_mesh(setup):
    """Continuous batching on the mesh engine reproduces solo per-request
    greedy tokens from a single-device engine."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    solo_eng = Engine(model, params, pol)
    eng = Engine(model, params, pol, mesh=ServingMesh.build((2, 2)))
    spec = [(8, 6), (12, 9), (8, 11), (10, 7), (9, 5)]
    reqs = [Request(uid=i, prompt=_prompts(cfg, (n,), seed=10 + i),
                    max_new_tokens=m)
            for i, (n, m) in enumerate(spec)]
    solo = {r.uid: _solo(solo_eng, r.prompt, r.max_new_tokens)
            for r in reqs}

    sched = Scheduler(eng, batch_slots=2, segment_len=4)
    sched.submit(reqs)
    done = sched.run()
    assert [c.uid for c in done] == [r.uid for r in reqs]
    for c in done:
        np.testing.assert_array_equal(np.asarray(c.tokens), solo[c.uid],
                                      err_msg=f"uid {c.uid}")
    s = sched.run_summary()
    assert s["mesh"] == eng.mesh.topology()
    assert s["mesh"]["axes"] == {"data": 2, "model": 2}


@pytest.mark.parametrize("kv_format", ["bf16", "int8"])
def test_preempt_resume_roundtrip_under_mesh(setup, kv_format):
    """Forced preemption-to-host + resume under the mesh changes no token:
    extract_slots gathers the sharded rows to host, insert_slots scatters
    them back onto the mesh layout."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0,
                      target_fill=0.5, kv_format=kv_format)
    solo_eng = Engine(model, params, pol)
    eng = Engine(model, params, pol, mesh=ServingMesh.build((2, 2)))
    spec = [(8, 12), (12, 10), (8, 14), (12, 11)]
    reqs = [ServeRequest(uid=i, prompt=_prompts(cfg, (n,), seed=30 + i),
                         max_new_tokens=m)
            for i, (n, m) in enumerate(spec)]
    solo = {r.uid: _solo(solo_eng, r.prompt, r.max_new_tokens)
            for r in reqs}

    core = FrontDoorCore(eng, batch_slots=2, segment_len=3,
                         admission=_transparent())
    core.submit(reqs)
    core.step()
    forced = 0
    for victim in (0, 1):
        if core.slots[victim] is not None:
            core.preempt_slot(victim)
            forced += 1
    assert forced >= 1
    core.step()
    if core.slots[0] is not None:
        core.preempt_slot(0)
        forced += 1
    done = core.run()

    assert [c.uid for c in done] == [r.uid for r in reqs]
    for c in done:
        np.testing.assert_array_equal(
            np.asarray(c.tokens), solo[c.uid],
            err_msg=f"uid {c.uid} (mesh/{kv_format})")
    s = core.run_summary()
    assert s["preempted"] == forced
    assert s["mesh"]["axes"] == {"data": 2, "model": 2}


def test_prefix_full_hit_under_mesh(setup):
    """The prefix store round-trips through the mesh: a repeated prompt is
    served from the host snapshot ("full" hit) with identical tokens."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol, mesh=ServingMesh.build((2, 2)))
    prompt = _prompts(cfg, (12,), seed=5)
    reqs = [Request(uid=0, prompt=prompt.copy(), max_new_tokens=6),
            Request(uid=1, prompt=prompt.copy(), max_new_tokens=6)]
    pc = PrefixCache(PrefixCacheConfig(block_size=8))
    sched = Scheduler(eng, batch_slots=1, segment_len=4, prefix_cache=pc)
    sched.submit(reqs)
    done = sched.run()
    assert [c.prefix_hit for c in done] == ["miss", "full"]
    np.testing.assert_array_equal(done[0].tokens, done[1].tokens)
    assert sched.run_summary()["prefix_full_hits"] == 1
