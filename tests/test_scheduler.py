"""Continuous-batching scheduler battery.

Covers the serving-core guarantees DESIGN.md §Serving promises:
  * differential — continuous batching reproduces solo per-request greedy
    tokens exactly, for every policy, under any admission order;
  * lifecycle/starvation fuzz (hypothesis) — random request mixes all
    complete exactly once, per-slot cache occupancy never exceeds capacity
    across refills;
  * slot isolation — reset/insert leave every other row bit-identical
    (and the slot ops donate their input buffers, PR-1 style);
  * EOS-aware early termination in both whole-request decode drivers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import cache as cache_lib
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.scheduler import (DECODING, FINISHED, PREFILLING, QUEUED,
                                     Request, Scheduler)


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, spec, seed=0):
    """spec: list of (prompt_len, max_new) tuples -> uid-ordered Requests."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=s).astype(np.int32),
                    max_new_tokens=n)
            for i, (s, n) in enumerate(spec)]


def _solo(engine, req, eos_id=None):
    """Reference: per-request greedy generate, truncated after EOS."""
    res = engine.generate({"tokens": jnp.asarray(req.prompt)[None, :]},
                          req.max_new_tokens, eos_id=eos_id)
    return np.asarray(res.tokens[0, :res.gen_lens[0]])


# --------------------------------------------------------------------------
# Differential: continuous == per-request greedy, all policies, any order
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "kind", ["lethe", "h2o", "streaming", "lazyeviction", "gkv"])
def test_continuous_matches_solo_generate(setup, kind):
    cfg, model, params = setup
    # lag_window small enough that lazyeviction's lagged eviction actually
    # fires inside these short generations (only lazyeviction reads it).
    pol = make_policy(kind, capacity=24, sink_len=2, sparse_ratio=4.0,
                      target_fill=0.5, lag_window=4)
    eng = Engine(model, params, pol)
    seed = {"lethe": 0, "h2o": 1, "streaming": 2,
            "lazyeviction": 3, "gkv": 4}[kind]
    reqs = _requests(cfg, [(8, 3), (12, 9), (8, 14), (12, 6), (8, 1),
                           (12, 11), (8, 7)], seed=seed)
    solo = {r.uid: _solo(eng, r) for r in reqs}

    sched = Scheduler(eng, batch_slots=3, segment_len=4)
    sched.submit(reqs)
    done = sched.run()
    assert [c.uid for c in done] == [r.uid for r in reqs]
    for c in done:
        np.testing.assert_array_equal(np.asarray(c.tokens), solo[c.uid],
                                      err_msg=f"uid {c.uid}")


def test_continuous_admission_order_invariant(setup):
    """Reversed submission order must not change any request's tokens —
    only its latency. (Neighbors can't leak into a slot's generation.)"""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    reqs = _requests(cfg, [(8, 4), (12, 10), (8, 8), (12, 5), (8, 12)],
                     seed=7)

    by_uid = {}
    for order in (list(reqs), list(reqs)[::-1]):
        sched = Scheduler(eng, batch_slots=2, segment_len=3)
        sched.submit(order)
        for c in sched.run():
            by_uid.setdefault(c.uid, []).append(np.asarray(c.tokens))
    for uid, (a, b) in by_uid.items():
        np.testing.assert_array_equal(a, b, err_msg=f"uid {uid}")


def test_lockstep_mode_still_drains(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=32, sink_len=2)
    eng = Engine(model, params, pol)
    reqs = _requests(cfg, [(8, 6)] * 5, seed=3)
    sched = Scheduler(eng, batch_slots=2)
    sched.submit(reqs)
    done = sched.run_lockstep()
    assert [c.uid for c in done] == list(range(5))
    assert all(len(c.tokens) == 6 for c in done)


# --------------------------------------------------------------------------
# Lifecycle + metrics
# --------------------------------------------------------------------------

def test_lifecycle_and_metrics(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    reqs = _requests(cfg, [(8, 5), (12, 9), (8, 2)], seed=5)
    sched = Scheduler(eng, batch_slots=2, segment_len=4)
    sched.submit(reqs)
    done = sched.run()
    for c in done:
        states = sched.lifecycle[c.uid]
        assert states[0] == QUEUED and states[-1] == FINISHED
        assert PREFILLING in states and DECODING in states
        assert states.count(FINISHED) == 1           # completed exactly once
        assert c.finish_reason == "length"
        assert c.decode_steps == len(c.tokens) - 1
        assert 0.0 <= c.queue_wait_s <= c.ttft_s
        assert c.tokens_per_second > 0


# --------------------------------------------------------------------------
# Starvation-freedom / capacity fuzz
# --------------------------------------------------------------------------

def _fuzz_case(setup, spec, slots, eos_id):
    """Invariants for one random request mix: every uid completes exactly
    once, within its token budget, and no slot's cache ever exceeds
    capacity across refills."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=16, sink_len=2, sparse_ratio=3.0,
                      target_fill=0.5)
    eng = Engine(model, params, pol)
    reqs = _requests(cfg, spec, seed=len(spec))
    sched = Scheduler(eng, batch_slots=slots, segment_len=3, eos_id=eos_id,
                      track_occupancy=True)
    sched.submit(reqs)
    done = sched.run()

    assert [c.uid for c in done] == list(range(len(reqs)))   # exactly once
    for c, r in zip(done, reqs):
        assert 1 <= len(c.tokens) <= r.max_new_tokens
        if c.finish_reason == "eos":
            assert c.tokens[-1] == eos_id
            assert not (c.tokens[:-1] == eos_id).any()
        else:
            assert len(c.tokens) == r.max_new_tokens
        assert sched.lifecycle[r.uid].count(FINISHED) == 1
    assert sched.max_slot_tokens <= pol.capacity
    assert not sched.queue                                   # fully drained


# prompt lengths drawn from a small set so jit compiles stay bounded
_LENS, _MAXNEW = (4, 6, 9), (1, 10)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    _REQ = st.tuples(st.sampled_from(_LENS),
                     st.integers(*_MAXNEW))

    @settings(max_examples=8, deadline=None)
    @given(st.lists(_REQ, min_size=1, max_size=9),
           st.sampled_from([1, 2, 3]),
           st.sampled_from([None, 0, 3]))
    def test_fuzz_no_starvation_no_overflow(setup, spec, slots, eos_id):
        """Hypothesis form: random request mixes (prompt lengths, budgets,
        EOS ids that random logits may or may not emit)."""
        _fuzz_case(setup, spec, slots, eos_id)
except ImportError:                          # pragma: no cover
    pass                                     # seeded sweep below still runs


@pytest.mark.parametrize("case_seed,slots,eos_id",
                         [(0, 1, None), (1, 2, 3), (2, 3, 0), (3, 2, None)])
def test_seeded_random_mixes(setup, case_seed, slots, eos_id):
    """Deterministic fallback sweep over random mixes — runs (unlike the
    hypothesis form) even where hypothesis isn't installed."""
    rng = np.random.default_rng(case_seed)
    n = int(rng.integers(1, 10))
    spec = [(int(rng.choice(_LENS)), int(rng.integers(*_MAXNEW) + 1))
            for _ in range(n)]
    _fuzz_case(setup, spec, slots, eos_id)


# --------------------------------------------------------------------------
# Slot isolation: reset/insert leave every other row bit-identical
# --------------------------------------------------------------------------

def _snapshot_rows(state, skip_slot):
    rows = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        arr = np.asarray(leaf)
        rows[jax.tree_util.keystr(path)] = np.delete(arr, skip_slot, axis=1)
    return rows


def test_slot_ops_leave_neighbors_bit_identical(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    B, target = 3, 1

    # build a live state: admit three requests, decode a segment
    state = eng.new_decode_state(B)
    rng = np.random.default_rng(0)
    for i in range(B):
        state, _ = eng.admit_slot(
            state, i, {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=10))[None, :]})
    state, _, pos, done = eng.decode_segment(
        state, np.zeros(B, np.int32), np.full(B, 10, np.int32),
        np.zeros(B, bool), 5)

    before = _snapshot_rows(state, target)

    # retire the middle slot...
    state = eng.release_slot(state, target)
    after_reset = _snapshot_rows(state, target)
    for name, arr in before.items():
        np.testing.assert_array_equal(arr, after_reset[name], err_msg=name)
    # ...the retired row really is empty
    assert int(np.asarray(state.length)[:, target].max()) == 0
    assert (np.asarray(state.pos)[:, target] == -1).all()

    # ...and refill it with a fresh (longer) request
    state, _ = eng.admit_slot(
        state, target, {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=14))[None, :]})
    after_insert = _snapshot_rows(state, target)
    for name, arr in before.items():
        np.testing.assert_array_equal(arr, after_insert[name], err_msg=name)

    # the KVCache-level ops give the same guarantee directly (transformer
    # decode state IS the cache)
    _, row = eng.prefill({"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=9))[None, :]})
    direct = cache_lib.insert_slot(cache_lib.reset_slot(state, target),
                                   target, row)
    for name, arr in _snapshot_rows(direct, target).items():
        np.testing.assert_array_equal(arr, after_insert[name], err_msg=name)


def test_refill_leaves_neighbor_rasr_scores_untouched(setup):
    """RASR scores of surviving slots must be bit-identical across a
    neighbor's retire+refill cycle (the per-row scoring guarantee)."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    state = eng.new_decode_state(2)
    rng = np.random.default_rng(1)
    for i in range(2):
        state, _ = eng.admit_slot(
            state, i, {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=12))[None, :]})
    score_before = np.asarray(state.score)[:, 0]
    budget_before = np.asarray(state.budget)[:, 0]
    state = eng.release_slot(state, 1)
    state, _ = eng.admit_slot(
        state, 1, {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=8))[None, :]})
    np.testing.assert_array_equal(np.asarray(state.score)[:, 0],
                                  score_before)
    np.testing.assert_array_equal(np.asarray(state.budget)[:, 0],
                                  budget_before)


def test_slot_ops_donate_buffers(setup):
    """PR-1-style acceptance: the slot insert/reset ops must update the
    live state in place — input K/V buffers deleted after the call."""
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=16, sink_len=2)
    eng = Engine(model, params, pol)
    state = eng.new_decode_state(2)
    old_k, old_v = state.k, state.v
    state, _ = eng.admit_slot(
        state, 0, {"tokens": jnp.asarray(np.arange(8))[None, :]})
    assert old_k.is_deleted() and old_v.is_deleted()
    old_k = state.k
    state = eng.release_slot(state, 0)
    assert old_k.is_deleted()


# --------------------------------------------------------------------------
# EOS-aware early termination in both whole-request drivers
# --------------------------------------------------------------------------

def test_generate_eos_early_termination_both_drivers(setup):
    cfg, model, params = setup
    pol = make_policy("h2o", capacity=24, sink_len=2)
    eng = Engine(model, params, pol)
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 10),
                                           0, cfg.vocab_size)}
    ref = eng.generate(prompt, 12)
    eos = int(ref.tokens[0, 4])      # row 0 will stop at step 5

    r_loop = eng.generate(prompt, 12, eos_id=eos)
    r_scan = eng.generate_scan(prompt, 12, eos_id=eos)
    np.testing.assert_array_equal(r_loop.tokens, r_scan.tokens)
    assert r_loop.steps == r_scan.steps
    assert r_loop.tokens.shape == (2, 12)            # padded to full width
    assert r_loop.finished[0] and r_loop.gen_lens[0] <= 5
    # frozen rows emit eos forever after finishing
    assert (r_loop.tokens[0, r_loop.gen_lens[0]:] == eos).all()
    # early termination: if every row finished, fewer steps than max_new
    if r_loop.finished.all():
        assert r_loop.steps < 12


def test_generate_eos_matches_scheduler(setup):
    cfg, model, params = setup
    pol = make_policy("lethe", capacity=24, sink_len=2, sparse_ratio=4.0)
    eng = Engine(model, params, pol)
    reqs = _requests(cfg, [(10, 12), (6, 12), (8, 12)], seed=11)
    probe = _solo(eng, reqs[0])
    eos = int(probe[3])
    solo = {r.uid: _solo(eng, r, eos_id=eos) for r in reqs}
    sched = Scheduler(eng, batch_slots=2, segment_len=4, eos_id=eos)
    sched.submit(reqs)
    for c in sched.run():
        np.testing.assert_array_equal(np.asarray(c.tokens), solo[c.uid])
        if c.tokens[-1] == eos:
            assert c.finish_reason == "eos"
