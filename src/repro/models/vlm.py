"""Qwen2-VL text backbone with M-RoPE and a stubbed vision frontend
[arXiv:2409.12191].

The ViT/projector is a STUB per the assignment: callers provide precomputed
patch embeddings [B, S_img, D]. This module builds the interleaved
(image-patches ++ text-tokens) input embedding and the three M-RoPE position
streams (temporal/height/width: image patches get 2-D grid positions at a
fixed timestamp; text tokens advance all three streams together), then
delegates to the generic transformer — decode inherits the full Lethe
machinery, so pruning operates over the *mixed* image+text cache.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.policy import PolicyConfig
from repro.models import common, transformer


init_params = transformer.init_params
init_decode_state = transformer.init_decode_state


def mrope_positions(B: int, s_img: int, s_text: int) -> jax.Array:
    """[3, B, S] position streams. Image patches: t=0, (h, w) on a near-
    square grid. Text: all three streams equal to the *sequence index* (so a
    decode step at sequence position p uses stream position p without needing
    to know the image extent — a simplification of Qwen2-VL's max(grid)+1
    start that keeps prefill and decode trivially consistent)."""
    if s_img:
        gw = max(1, int(math.sqrt(s_img)))
        idx = jnp.arange(s_img)
        img_t = jnp.zeros((s_img,), jnp.int32)
        img_h = (idx // gw).astype(jnp.int32)
        img_w = (idx % gw).astype(jnp.int32)
    else:
        img_t = img_h = img_w = jnp.zeros((0,), jnp.int32)
    text = jnp.arange(s_text, dtype=jnp.int32) + s_img
    t = jnp.concatenate([img_t, text])
    h = jnp.concatenate([img_h, text])
    w = jnp.concatenate([img_w, text])
    pos3 = jnp.stack([t, h, w])                      # [3, S]
    return jnp.broadcast_to(pos3[:, None, :], (3, B, s_img + s_text))


def build_inputs(params: dict, tokens: jax.Array, cfg: ArchConfig,
                 img_embeds: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """-> (embeds [B, S_total, D], positions3 [3, B, S_total])."""
    B = tokens.shape[0]
    text = common.embed_tokens(tokens, params, cfg)
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(text.dtype), text], axis=1)
        s_img = img_embeds.shape[1]
    else:
        x = text
        s_img = 0
    pos3 = mrope_positions(B, s_img, tokens.shape[1])
    return x, pos3


@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params, tokens, cfg: ArchConfig, *,
                  img_embeds: jax.Array | None = None, **_):
    x, pos3 = build_inputs(params, tokens, cfg, img_embeds)
    return transformer.forward_train(params, tokens, cfg, embeds=x,
                                     positions3=pos3)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "capacity",
                                             "cache_dtype"))
def prefill(params, tokens, cfg: ArchConfig, policy: PolicyConfig, *,
            img_embeds: jax.Array | None = None, capacity=None,
            cache_dtype=jnp.float32, **_):
    x, pos3 = build_inputs(params, tokens, cfg, img_embeds)
    # transformer.prefill keys its shapes off `tokens`; pass a dummy token
    # array covering the full (img+text) sequence.
    full_tokens = jnp.zeros((tokens.shape[0], x.shape[1]), jnp.int32)
    return transformer.prefill(params, full_tokens, cfg, policy,
                               capacity=capacity, embeds=x, positions3=pos3,
                               cache_dtype=cache_dtype)


@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("cache",))
def decode_step(params, cache, token, cur_pos, cfg: ArchConfig,
                policy: PolicyConfig, **_):
    # Donation must be declared on this outer jit — the inner
    # transformer.decode_step jit is inlined when traced from here.
    B = token.shape[0]
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))
    pos3 = jnp.broadcast_to(cur[None], (3, B))  # text: streams move together
    return transformer.decode_step(params, cache, token, cur_pos, cfg,
                                   policy, positions3=pos3)
