"""Qwen2-VL text backbone with M-RoPE and a stubbed vision frontend
[arXiv:2409.12191].

The ViT/projector is a STUB per the assignment: callers provide precomputed
patch embeddings [B, S_img, D]. This module builds the interleaved
(image-patches ++ text-tokens) input embedding and the three M-RoPE position
streams (temporal/height/width: image patches get 2-D grid positions at a
fixed timestamp; text tokens advance all three streams together), then
delegates to the generic transformer — decode inherits the full Lethe
machinery, so pruning operates over the *mixed* image+text cache.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.policy import PolicyConfig
from repro.models import common, transformer


init_params = transformer.init_params
init_decode_state = transformer.init_decode_state


def mrope_positions(B: int, s_img: int, s_text: int) -> jax.Array:
    """[3, B, S] position streams. Image patches: t=0, (h, w) on a near-
    square grid. Text: all three streams equal to the *sequence index* (so a
    decode step at sequence position p uses stream position p without needing
    to know the image extent — a simplification of Qwen2-VL's max(grid)+1
    start that keeps prefill and decode trivially consistent)."""
    if s_img:
        gw = max(1, int(math.sqrt(s_img)))
        idx = jnp.arange(s_img)
        img_t = jnp.zeros((s_img,), jnp.int32)
        img_h = (idx // gw).astype(jnp.int32)
        img_w = (idx % gw).astype(jnp.int32)
    else:
        img_t = img_h = img_w = jnp.zeros((0,), jnp.int32)
    text = jnp.arange(s_text, dtype=jnp.int32) + s_img
    t = jnp.concatenate([img_t, text])
    h = jnp.concatenate([img_h, text])
    w = jnp.concatenate([img_w, text])
    pos3 = jnp.stack([t, h, w])                      # [3, S]
    return jnp.broadcast_to(pos3[:, None, :], (3, B, s_img + s_text))


def build_inputs(params: dict, tokens: jax.Array, cfg: ArchConfig,
                 img_embeds: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """-> (embeds [B, S_total, D], positions3 [3, B, S_total])."""
    B = tokens.shape[0]
    text = common.embed_tokens(tokens, params, cfg)
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(text.dtype), text], axis=1)
        s_img = img_embeds.shape[1]
    else:
        x = text
        s_img = 0
    pos3 = mrope_positions(B, s_img, tokens.shape[1])
    return x, pos3


@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params, tokens, cfg: ArchConfig, *,
                  img_embeds: jax.Array | None = None, **_):
    x, pos3 = build_inputs(params, tokens, cfg, img_embeds)
    return transformer.forward_train(params, tokens, cfg, embeds=x,
                                     positions3=pos3)


def prefill(params, tokens, cfg: ArchConfig, policy: PolicyConfig, *,
            img_embeds: jax.Array | None = None, capacity=None,
            cache_dtype=jnp.float32, **_):
    # Orchestrator, deliberately NOT jitted: transformer.prefill routes the
    # tail pipeline through the shared `chunked.finalize_pipeline` program
    # (jitting here would inline and re-fuse it, breaking the bit-identity
    # contract with chunked admission).
    x, pos3 = _build_inputs_jit(params, tokens, cfg, img_embeds)
    # transformer.prefill keys its shapes off `tokens`; pass a dummy token
    # array covering the full (img+text) sequence.
    full_tokens = jnp.zeros((tokens.shape[0], x.shape[1]), jnp.int32)
    return transformer.prefill(params, full_tokens, cfg, policy,
                               capacity=capacity, embeds=x, positions3=pos3,
                               cache_dtype=cache_dtype)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _build_inputs_jit(params, tokens, cfg: ArchConfig,
                      img_embeds: jax.Array | None = None):
    return build_inputs(params, tokens, cfg, img_embeds)


# --------------------------------------------------------------------------
# Chunked prefill: chunks span the *combined* (image patches ++ text)
# sequence; the precomputed input embeddings and M-RoPE streams live in the
# carry and are sliced per chunk.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "policy", "chunk_max",
                                             "capacity", "cache_dtype"))
def prefill_chunk_init(params, tokens, cfg: ArchConfig,
                       policy: PolicyConfig, *, chunk_max: int,
                       capacity: int | None = None,
                       cache_dtype=jnp.float32,
                       img_embeds: jax.Array | None = None, **_) -> dict:
    x, pos3 = build_inputs(params, tokens, cfg, img_embeds)
    carry = transformer.prefill_chunk_init(
        params, tokens, cfg, policy, chunk_max=chunk_max,
        capacity=capacity, cache_dtype=cache_dtype)
    carry["extra"] = {"embeds": x, "pos3": pos3}
    return carry


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "n",
                                             "capacity", "compress",
                                             "contiguous_offset"),
                   donate_argnames=("carry",))
def prefill_chunk(params, carry, tokens, cfg: ArchConfig,
                  policy: PolicyConfig, *, n: int,
                  capacity: int | None = None, compress: bool = False,
                  contiguous_offset: int | None = None) -> dict:
    del tokens   # chunk content comes from the precomputed embeddings
    done = jnp.asarray(carry["done"], jnp.int32)
    emb = jax.lax.dynamic_slice_in_dim(carry["extra"]["embeds"], done, n,
                                       axis=1)
    pos3 = jax.lax.dynamic_slice_in_dim(carry["extra"]["pos3"], done, n,
                                        axis=2)
    return transformer._prefill_chunk_impl(
        params, carry, None, cfg, policy, capacity=capacity,
        compress=compress, contiguous_offset=contiguous_offset,
        embeds=emb, positions3=pos3)


prefill_finalize = transformer.prefill_finalize


@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("cache",))
def decode_step(params, cache, token, cur_pos, cfg: ArchConfig,
                policy: PolicyConfig, **_):
    # Donation must be declared on this outer jit — the inner
    # transformer.decode_step jit is inlined when traced from here.
    B = token.shape[0]
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))
    pos3 = jnp.broadcast_to(cur[None], (3, B))  # text: streams move together
    return transformer.decode_step(params, cache, token, cur_pos, cfg,
                                   policy, positions3=pos3)
