"""Uniform model API over the heterogeneous architecture families.

``build_model(cfg)`` dispatches on family and returns a ``ModelAPI`` whose
five entry points have identical signatures across all 10 assigned archs.
``batch`` is a dict pytree: {"tokens": [B,S]} plus optional modality extras
("enc_frames" [B,S_enc,D] for audio, "img_embeds" [B,S_img,D] for VLM).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import cache as cache_lib
from repro.core.policy import PolicyConfig
from repro.models import rglru, rwkv6, transformer, vlm, whisper


def _extras(batch: dict) -> dict:
    return {k: v for k, v in batch.items()
            if k in ("enc_frames", "img_embeds") and v is not None}


@dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    module: Any

    def init(self, key, dtype=jnp.float32, **kw):
        return self.module.init_params(self.cfg, key, dtype=dtype, **kw)

    def forward_train(self, params, batch: dict):
        return self.module.forward_train(
            params, batch["tokens"], self.cfg, **_extras(batch))

    def prefill(self, params, batch: dict, policy: PolicyConfig, *,
                capacity: int | None = None, cache_dtype=jnp.float32):
        return self.module.prefill(
            params, batch["tokens"], self.cfg, policy, capacity=capacity,
            cache_dtype=cache_dtype, **_extras(batch))

    def decode_step(self, params, state, token, cur_pos,
                    policy: PolicyConfig):
        return self.module.decode_step(
            params, state, token, cur_pos, self.cfg, policy)

    def init_decode_state(self, policy: PolicyConfig, batch_size: int,
                          dtype=jnp.float32, **kw):
        return self.module.init_decode_state(
            self.cfg, policy, batch_size, dtype=dtype, **kw)

    def prefill_into_slot(self, params, batch: dict, policy: PolicyConfig,
                          state, slots, *, cache_dtype=jnp.float32):
        """Slot-scoped prefill — the admission primitive of continuous
        batching. Prefills a group of requests (``batch`` has batch size k,
        row j destined for live slot ``slots[j]``) through the normal
        per-family prefill (so each row's RASR scores, per-layer budgets
        and forced prune round are exactly those of a solo run), then
        overwrites the addressed batch rows of the live decode ``state``
        with the resulting rows — a donated masked select, so every other
        slot's K/V, scores, and budget state passes through bit-identically
        and ``state`` is consumed.

        Returns (last-token logits [k, V], new state).
        """
        logits, rows = self.prefill(params, batch, policy,
                                    cache_dtype=cache_dtype)
        state = cache_lib.update_slots_donated(
            state, jnp.asarray(slots, jnp.int32), rows)
        return logits, state


_FAMILY_MODULES = {
    "ssm": rwkv6,
    "hybrid": rglru,
    "audio": whisper,
    "vlm": vlm,
    "dense": transformer,
    "moe": transformer,
}


def build_model(cfg: ArchConfig) -> ModelAPI:
    return ModelAPI(cfg=cfg, module=_FAMILY_MODULES[cfg.family])
