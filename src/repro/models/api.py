"""Uniform model API over the heterogeneous architecture families.

``build_model(cfg)`` dispatches on family and returns a ``ModelAPI`` whose
five entry points have identical signatures across all 10 assigned archs.
``batch`` is a dict pytree: {"tokens": [B,S]} plus optional modality extras
("enc_frames" [B,S_enc,D] for audio, "img_embeds" [B,S_img,D] for VLM).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import cache as cache_lib
from repro.core.policy import PolicyConfig
from repro.models import rglru, rwkv6, transformer, vlm, whisper


def _extras(batch: dict) -> dict:
    return {k: v for k, v in batch.items()
            if k in ("enc_frames", "img_embeds") and v is not None}


def check_kv_format(cfg: ArchConfig, policy: PolicyConfig) -> None:
    """Config-time admission check for the cache storage format: a clear
    host-side error instead of a shape/dtype failure deep inside jit.

    ``kv_format="int8"`` quantizes the slotted KV cache; families whose
    decode state is (wholly or partly) a recurrence — rwkv6's wkv matrices,
    recurrentgemma's RG-LRU hidden state — carry no per-token K/V for those
    layers and are out of scope.
    """
    if getattr(policy, "kv_format", "bf16") == "bf16":
        return
    from repro.configs.base import RGLRU, RWKV
    recurrent = sorted({k for k in cfg.layer_kinds if k in (RWKV, RGLRU)})
    if recurrent or not cfg.has_kv_cache:
        raise ValueError(
            f"kv_format='int8' is unsupported for arch {cfg.name!r} "
            f"(family {cfg.family!r}): layer kinds {recurrent or 'none'} "
            "carry recurrent state, not a slotted KV cache. Quantized "
            "retention applies to attention families only "
            "(dense/moe/vlm/audio); use kv_format='bf16' here.")


@dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    module: Any

    def init(self, key, dtype=jnp.float32, **kw):
        return self.module.init_params(self.cfg, key, dtype=dtype, **kw)

    def forward_train(self, params, batch: dict):
        return self.module.forward_train(
            params, batch["tokens"], self.cfg, **_extras(batch))

    def prefill(self, params, batch: dict, policy: PolicyConfig, *,
                capacity: int | None = None, cache_dtype=jnp.float32):
        check_kv_format(self.cfg, policy)
        return self.module.prefill(
            params, batch["tokens"], self.cfg, policy, capacity=capacity,
            cache_dtype=cache_dtype, **_extras(batch))

    def decode_step(self, params, state, token, cur_pos,
                    policy: PolicyConfig):
        return self.module.decode_step(
            params, state, token, cur_pos, self.cfg, policy)

    def init_decode_state(self, policy: PolicyConfig, batch_size: int,
                          dtype=jnp.float32, **kw):
        check_kv_format(self.cfg, policy)
        return self.module.init_decode_state(
            self.cfg, policy, batch_size, dtype=dtype, **kw)

    # ---- chunked prefill (DESIGN.md §Prefill) -----------------------------

    def total_prompt_len(self, batch: dict) -> int:
        """Combined prefill sequence length (image patches + text for VLM;
        decoder tokens for the audio family)."""
        s_img = (batch.get("img_embeds").shape[1]
                 if batch.get("img_embeds") is not None else 0)
        return batch["tokens"].shape[1] + s_img

    def chunked_compress(self, policy: PolicyConfig, s_total: int,
                         capacity: int | None = None) -> bool:
        """THE admission decision for chunked prefill (one spelling, shared
        by the engine and the one-shot driver): whether prefill-phase
        compression must run for a prompt of ``s_total`` tokens — and a
        ``ValueError`` when it must but the policy cannot evict.
        Recurrence-only families (O(1) state, no KV cache) accept any
        length without compression."""
        C = capacity or policy.capacity
        compress = s_total > C and self.cfg.family != "ssm"
        if compress and not policy.prunes:
            raise ValueError(
                f"prompt of {s_total} tokens exceeds capacity {C} and "
                f"policy {policy.kind!r} cannot evict")
        return compress

    def prefill_chunk_init(self, params, batch: dict, policy: PolicyConfig,
                           *, chunk_max: int, capacity: int | None = None,
                           cache_dtype=jnp.float32):
        """Fresh chunked-prefill carry for one admission group (working
        buffers + family state: VLM pre-embeds the combined sequence, the
        audio family runs its encoder here). Outside the VLM family only
        the batch *width* matters, so the token array is sliced to one
        column — init compiles once per width, not once per prompt
        length."""
        check_kv_format(self.cfg, policy)
        toks = batch["tokens"]
        if self.cfg.family != "vlm":
            toks = toks[:, :1]
        return self.module.prefill_chunk_init(
            params, toks, self.cfg, policy, chunk_max=chunk_max,
            capacity=capacity, cache_dtype=cache_dtype, **_extras(batch))

    def prefill_chunk_resume(self, params, rows, policy: PolicyConfig, *,
                             chunk_max: int, s_prefix: int,
                             capacity: int | None = None,
                             cache_dtype=jnp.float32):
        """Chunked-prefill carry seeded from restored prefix rows (the
        prefix-reuse partial-hit path) instead of an empty buffer. Only
        attention families whose decode state is the bare slotted cache
        support resume; others raise the typed admission ``ValueError``
        (callers fall back to a cold prefill)."""
        check_kv_format(self.cfg, policy)
        fn = getattr(self.module, "prefill_chunk_resume", None)
        if fn is None or not isinstance(rows, cache_lib.KVCache):
            raise ValueError(
                f"prefix resume is unsupported for arch {self.cfg.name!r} "
                f"(family {self.cfg.family!r}): the decode state is not a "
                "bare slotted KV cache")
        return fn(params, rows, self.cfg, policy, chunk_max=chunk_max,
                  s_prefix=s_prefix, capacity=capacity,
                  cache_dtype=cache_dtype)

    def prefill_chunk(self, params, carry, tokens_chunk, policy:
                      PolicyConfig, *, n: int, capacity: int | None = None,
                      compress: bool = False,
                      contiguous_offset: int | None = None):
        """Advance the carry by one prompt chunk (``tokens_chunk`` [B, n];
        None for the VLM family, whose chunks come from the pre-embedded
        combined sequence). ``compress`` turns on mid-prefill scoring and
        the compression round (prompts longer than capacity)."""
        return self.module.prefill_chunk(
            params, carry, tokens_chunk, self.cfg, policy, n=n,
            capacity=capacity, compress=compress,
            contiguous_offset=contiguous_offset)

    def prefill_finalize(self, params, carry, policy: PolicyConfig, *,
                         s_total: int, capacity: int | None = None):
        """Carry -> (last-token logits [B, V], decode state) — the same
        contract as ``prefill``. The observation window and the bucketed
        statistics extent both derive from ``s_total`` (the combined
        prompt length), so finalize programs are shared per power-of-two
        length bucket, not per length."""
        from repro.models import chunked
        C = capacity or policy.capacity
        return self.module.prefill_finalize(
            params, carry, self.cfg, policy,
            w_eff=min(policy.obs_window, s_total),
            k_extent=chunked.finalize_extent(s_total, C),
            capacity=capacity)

    def prefill_chunked(self, params, batch: dict, policy: PolicyConfig, *,
                        chunk_plan: tuple[int, ...],
                        capacity: int | None = None,
                        cache_dtype=jnp.float32):
        """One-shot chunked prefill: drive every chunk of ``chunk_plan``
        (which must sum to the combined prompt length) then finalize.
        Differentially equal to ``prefill`` for prompts that fit capacity;
        longer prompts stream through prefill-phase compression."""
        S_total = self.total_prompt_len(batch)
        assert sum(chunk_plan) == S_total, (chunk_plan, S_total)
        # admission decision before any device work (encoder etc.)
        compress = self.chunked_compress(policy, S_total, capacity)
        carry = self.prefill_chunk_init(
            params, batch, policy, chunk_max=max(chunk_plan),
            capacity=capacity, cache_dtype=cache_dtype)
        if "buf" not in carry:
            compress = False
        toks = batch["tokens"]
        done = 0
        for n in chunk_plan:
            chunk = (None if self.cfg.family == "vlm"
                     else jnp.asarray(toks[:, done:done + n]))
            carry = self.prefill_chunk(
                params, carry, chunk, policy, n=n, capacity=capacity,
                compress=compress)
            done += n
        return self.prefill_finalize(
            params, carry, policy, s_total=S_total, capacity=capacity)

    def prefill_into_slot(self, params, batch: dict, policy: PolicyConfig,
                          state, slots, *, cache_dtype=jnp.float32):
        """Slot-scoped prefill — the admission primitive of continuous
        batching. Prefills a group of requests (``batch`` has batch size k,
        row j destined for live slot ``slots[j]``) through the normal
        per-family prefill (so each row's RASR scores, per-layer budgets
        and forced prune round are exactly those of a solo run), then
        overwrites the addressed batch rows of the live decode ``state``
        with the resulting rows — a donated masked select, so every other
        slot's K/V, scores, and budget state passes through bit-identically
        and ``state`` is consumed.

        Returns (last-token logits [k, V], new state).
        """
        logits, rows = self.prefill(params, batch, policy,
                                    cache_dtype=cache_dtype)
        state = cache_lib.update_slots_donated(
            state, jnp.asarray(slots, jnp.int32), rows)
        return logits, state


_FAMILY_MODULES = {
    "ssm": rwkv6,
    "hybrid": rglru,
    "audio": whisper,
    "vlm": vlm,
    "dense": transformer,
    "moe": transformer,
}


def build_model(cfg: ArchConfig) -> ModelAPI:
    return ModelAPI(cfg=cfg, module=_FAMILY_MODULES[cfg.family])
