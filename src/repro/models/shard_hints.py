"""Optional intra-function sharding hints (env-gated §Perf variants).

REPRO_PREFILL_SEQ_SHARD=1 — context-parallel prefill attention: Q and the
attention output are sharded along the *sequence* axis on ``model`` while the
(small, GQA) K/V are replicated across ``model``. This kills the pathology
found in the qwen2.5-32b × prefill_32k baseline: with a ragged head count
(40 heads / 16-way), GSPMD shards the QK contraction (head_dim) and
all-reduces S×S score matrices (~2.9 TB/chip). Sequence-sharded scores are
fully local.
"""
from __future__ import annotations

import os

import jax
from jax.sharding import PartitionSpec as P


def seq_shard_prefill() -> bool:
    return os.environ.get("REPRO_PREFILL_SEQ_SHARD", "0") == "1"


def hint(x, *spec):
    """with_sharding_constraint that degrades to a no-op outside a mesh."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, NameError):
        return x


def prefill_attention_hints(qh, kh, vh):
    """qh [B,Hq,S,Dh]; kh/vh [B,Hkv,S,Dh]."""
    if not seq_shard_prefill():
        return qh, kh, vh
    qh = hint(qh, "data", None, "model", None)
    kh = hint(kh, "data", None, None, None)
    vh = hint(vh, "data", None, None, None)
    return qh, kh, vh


def prefill_out_hint(attn_raw):
    """attn_raw [B,Hq,S,Dh] — keep the sequence axis model-sharded."""
    if not seq_shard_prefill():
        return attn_raw
    return hint(attn_raw, "data", None, "model", None)
