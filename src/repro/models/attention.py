"""GQA attention with Lethe-managed decode cache.

Three call modes:
  * full-sequence (train / prefill compute)      -> attend_full
  * single-token decode over a slotted cache     -> decode_attend
(Prefill RASR/sparsity statistics live in ``chunked.finalize_pipeline`` —
the one compiled tail program shared by whole-prompt and chunked prefill.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import cache as cache_lib
from repro.core import sparsity as sparsity_lib
from repro.core.policy import PolicyConfig
from repro.kernels import ops
from repro.models import common


def init_attention(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, dh, hq, hkv = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], (d, hq * dh), dtype),
        "wk": common.dense_init(ks[1], (d, hkv * dh), dtype),
        "wv": common.dense_init(ks[2], (d, hkv * dh), dtype),
        "wo": common.dense_init(ks[3], (hq * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def project_qkv(x: jax.Array, p: dict, cfg: ArchConfig
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x [..., D] -> q [..., Hq, Dh], k/v [..., Hkv, Dh]."""
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*x.shape[:-1], cfg.n_heads, cfg.d_head)
    k = k.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_head)
    return q, k, v


def _rope(q, k, positions, cfg: ArchConfig, positions3=None):
    """q/k: [B, S, H, Dh] rotated at ``positions`` [B, S] (or M-RoPE
    ``positions3`` [3, B, S])."""
    if not cfg.use_rope:
        return q, k
    qh = jnp.swapaxes(q, -3, -2)  # [B, H, S, Dh]
    kh = jnp.swapaxes(k, -3, -2)
    if cfg.mrope and positions3 is not None:
        p3 = positions3[:, :, None, :]  # [3, B, 1, S]
        qh = common.apply_mrope(qh, p3, cfg.rope_theta, cfg.mrope_sections)
        kh = common.apply_mrope(kh, p3, cfg.rope_theta, cfg.mrope_sections)
    else:
        pos = positions[:, None, :]     # [B, 1, S]
        qh = common.apply_rope(qh, pos, cfg.rope_theta)
        kh = common.apply_rope(kh, pos, cfg.rope_theta)
    return jnp.swapaxes(qh, -3, -2), jnp.swapaxes(kh, -3, -2)


def attend_full(x: jax.Array, p: dict, cfg: ArchConfig, *,
                window=None, positions: jax.Array | None = None,
                positions3: jax.Array | None = None,
                causal: bool = True,
                return_kv: bool = False):
    """Full-sequence attention. x [B, S, D] -> out [B, S, D].

    ``window`` may be a traced per-layer scalar (gemma2's alternating
    local/global inside one layer-scan); a sentinel >= seq_len means global.
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = project_qkv(x, p, cfg)
    q, k = _rope(q, k, positions, cfg, positions3)
    qh = jnp.swapaxes(q, 1, 2)   # [B, Hq, S, Dh]
    kh = jnp.swapaxes(k, 1, 2)   # [B, Hkv, S, Dh]
    vh = jnp.swapaxes(v, 1, 2)
    out = ops.prefill_attention(
        qh, kh, vh, causal=causal, window=window,
        softcap=cfg.attn_logit_softcap, scale=cfg.d_head ** -0.5)
    out = jnp.swapaxes(out, 1, 2).reshape(B, S, -1) @ p["wo"]
    if return_kv:
        return out, (kh, vh)
    return out


def decode_attend(x: jax.Array, p: dict, layer: cache_lib.KVCache,
                  cur_pos, cfg: ArchConfig, policy: PolicyConfig, *,
                  window=None, positions3=None,
                  prune: bool = True) -> tuple[jax.Array, cache_lib.KVCache]:
    """One decode step for one layer. x [B, D] -> (attn_out [B, D], cache').

    ``cur_pos`` may be a scalar (all rows at one position — lockstep decode)
    or [B] (continuous batching: each slot hosts a request at its own
    position). Appends the token's K/V, runs the fused masked-attention +
    RASR kernel (attention output, probability column-sums, and the Eq. 5
    score EMA in one pass — no separate ``rasr.update_scores`` sweep over
    [B, C]), updates the per-row layerwise sparsity estimate, then runs the
    (conditionally triggered) pruning round. The cache's ``length`` bounds
    the kernel's occupancy-adaptive early exit, so attention cost tracks
    live tokens.
    """
    B, D = x.shape
    q, k, v = project_qkv(x[:, None, :], p, cfg)   # [B, 1, H, Dh]
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))
    q, k = _rope(q, k, cur[:, None], cfg,
                 positions3 if positions3 is None else positions3[:, :, None])
    q1 = q[:, 0]                                   # [B, Hq, Dh]
    k1 = jnp.swapaxes(k, 1, 2)[:, :, 0]            # [B, Hkv, Dh]
    v1 = jnp.swapaxes(v, 1, 2)[:, :, 0]

    layer = cache_lib.append_token(layer, k1, v1, cur, policy.init_score)
    out, probsum, new_score = ops.decode_attention_fused(
        q1, layer.k, layer.v, layer.pos, cur, layer.score,
        gamma=policy.gamma, window=window, softcap=cfg.attn_logit_softcap,
        scale=cfg.d_head ** -0.5, lengths=layer.length,
        k_scale=layer.k_scale, v_scale=layer.v_scale)
    layer = dataclasses.replace(layer, score=new_score)
    # per-row layerwise sparsity EMA from this step's head-aggregated
    # attention (each slot tracks its own request's profile)
    valid = cache_lib.valid_mask(layer.pos)
    p_norm = probsum / cfg.n_heads
    obs = sparsity_lib.row_sparsity_from_probs(
        p_norm, where=valid, n_valid=jnp.maximum(layer.length, 2))
    new_spars = sparsity_lib.update_sparsity_ema(
        layer.sparsity, obs, policy.sparsity_ema)
    layer = dataclasses.replace(layer, sparsity=new_spars)

    if prune and policy.prunes:
        from repro.core import pruning
        layer = pruning.prune_layer(layer, cur, policy=policy,
                                    window=window)
    attn_out = out.reshape(B, -1) @ p["wo"]
    return attn_out, layer
