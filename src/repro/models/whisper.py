"""Whisper large-v3 backbone — encoder-decoder transformer with a stubbed
audio frontend [arXiv:2212.04356].

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: ``input_specs``/callers provide precomputed frame embeddings
[B, S_enc, D]. Everything downstream — sinusoidal encoder, causal decoder
with learned positions, cross-attention — is implemented.

Lethe applies to the decoder *self*-attention cache. The cross-attention
cache is computed once from the encoder output and is static (encoder-length)
— it is exempt from pruning by design (DESIGN.md §Arch-applicability), and
likewise exempt from int8 KV quantization (``kv_format="int8"`` quantizes
the pruned self-attention cache only; the cross K/V are written once, read
every step, and stay at ``cache_dtype``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import cache as cache_lib
from repro.core.policy import LETHE, PolicyConfig
from repro.models import attention, common
from repro.models.scan_config import layer_scan


def _init_enc_layer(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    return {
        "norm": common.init_norm(ks[0], cfg.d_model, cfg, dtype),
        "attn": attention.init_attention(ks[1], cfg, dtype),
        "ffn_norm": common.init_norm(ks[2], cfg.d_model, cfg, dtype),
        "mlp": common.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg, dtype),
    }


def _init_dec_layer(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    return {
        "norm": common.init_norm(ks[0], cfg.d_model, cfg, dtype),
        "attn": attention.init_attention(ks[1], cfg, dtype),
        "xnorm": common.init_norm(ks[2], cfg.d_model, cfg, dtype),
        "xattn": attention.init_attention(ks[3], cfg, dtype),
        "ffn_norm": common.init_norm(ks[4], cfg.d_model, cfg, dtype),
        "mlp": common.init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg, dtype),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.float32,
                max_positions: int = 4096) -> dict:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": common.embed_init(ks[2], (cfg.vocab_size, cfg.d_model),
                                   dtype),
        "pos_embed": common.embed_init(ks[3], (max_positions, cfg.d_model),
                                       dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            dec_keys),
        "enc_final_norm": common.init_norm(ks[4], cfg.d_model, cfg, dtype),
        "final_norm": common.init_norm(ks[5], cfg.d_model, cfg, dtype),
    }


# --------------------------------------------------------------------------
# Encoder
# --------------------------------------------------------------------------

def encode(params: dict, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames [B, S_enc, D] (stub frontend output) -> encoder states."""
    S = frames.shape[1]
    x = frames + common.sinusoidal_positions(S, cfg.d_model).astype(
        frames.dtype)

    def body(carry, lp):
        h = common.apply_norm(carry, lp["norm"], cfg)
        out = attention.attend_full(h, lp["attn"], cfg, causal=False)
        y = carry + out
        h2 = common.apply_norm(y, lp["ffn_norm"], cfg)
        y = y + common.apply_mlp(h2, lp["mlp"], cfg)
        return y, None

    x, _ = layer_scan(body, x, params["enc_layers"])
    return common.apply_norm(x, params["enc_final_norm"], cfg)


def _cross_kv(params: dict, enc_out: jax.Array, cfg: ArchConfig,
              dtype) -> tuple[jax.Array, jax.Array]:
    """Precompute per-decoder-layer cross-attention K/V [L, B, Hkv, S, Dh]."""
    def body(_, lp):
        h = enc_out
        k = (h @ lp["xattn"]["wk"]).reshape(
            *h.shape[:-1], cfg.n_kv_heads, cfg.d_head)
        v = (h @ lp["xattn"]["wv"]).reshape(
            *h.shape[:-1], cfg.n_kv_heads, cfg.d_head)
        return None, (jnp.swapaxes(k, 1, 2).astype(dtype),
                      jnp.swapaxes(v, 1, 2).astype(dtype))

    _, (ks, vs) = layer_scan(body, None, params["dec_layers"])
    return ks, vs


def _cross_attend_full(x, lp, ck, cv, cfg):
    """x [B, S, D] cross-attends to precomputed enc K/V [B, Hkv, T, Dh]."""
    from repro.kernels import ops
    B, S, D = x.shape
    q = (x @ lp["xattn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    qh = jnp.swapaxes(q, 1, 2)
    out = ops.prefill_attention(qh, ck, cv, causal=False,
                                scale=cfg.d_head ** -0.5)
    return jnp.swapaxes(out, 1, 2).reshape(B, S, -1) @ lp["xattn"]["wo"]


# --------------------------------------------------------------------------
# Decoder full-sequence (train / prefill compute)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params: dict, tokens: jax.Array, cfg: ArchConfig, *,
                  enc_frames: jax.Array, **_
                  ) -> tuple[jax.Array, jax.Array]:
    enc_out = encode(params, enc_frames, cfg)
    ck, cv = _cross_kv(params, enc_out, cfg, enc_out.dtype)
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][:S]

    def body(carry, xs):
        lp, ck_l, cv_l = xs
        h = common.apply_norm(carry, lp["norm"], cfg)
        out = attention.attend_full(h, lp["attn"], cfg, causal=True)
        y = carry + out
        h2 = common.apply_norm(y, lp["xnorm"], cfg)
        y = y + _cross_attend_full(h2, lp, ck_l, cv_l, cfg)
        h3 = common.apply_norm(y, lp["ffn_norm"], cfg)
        y = y + common.apply_mlp(h3, lp["mlp"], cfg)
        return y, None

    x, _ = layer_scan(body, x, (params["dec_layers"], ck, cv))
    x = common.apply_norm(x, params["final_norm"], cfg)
    logits = x @ params["embed"].T
    return logits, jnp.float32(0.0)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "cache_dtype"))
def _prefill_compute(params: dict, tokens: jax.Array, cfg: ArchConfig,
                     policy: PolicyConfig, *, enc_frames: jax.Array,
                     cache_dtype=jnp.float32):
    """Decoder prefill compute (encoder + cross K/V + per-layer self K/V +
    observation-window query tail); the cache-construction tail runs in the
    shared ``chunked.finalize_pipeline`` (see ``prefill``)."""
    enc_out = encode(params, enc_frames, cfg)
    ck, cv = _cross_kv(params, enc_out, cfg, cache_dtype)
    B, S = tokens.shape
    W = policy.obs_window
    w_eff = min(W, S)
    x = params["embed"][tokens] + params["pos_embed"][:S]

    def body(carry, xs):
        lp, ck_l, cv_l = xs
        h = common.apply_norm(carry, lp["norm"], cfg)
        q, k, v = attention.project_qkv(h, lp["attn"], cfg)
        qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
        from repro.kernels import ops
        raw = ops.prefill_attention(qh, kh, vh, causal=True,
                                    scale=cfg.d_head ** -0.5)
        out = jnp.swapaxes(raw, 1, 2).reshape(B, S, -1) @ lp["attn"]["wo"]
        q_tail = jnp.pad(qh[:, :, S - w_eff:].astype(jnp.float32),
                         ((0, 0), (0, 0), (W - w_eff, 0), (0, 0)))
        y = carry + out
        h2 = common.apply_norm(y, lp["xnorm"], cfg)
        y = y + _cross_attend_full(h2, lp, ck_l, cv_l, cfg)
        h3 = common.apply_norm(y, lp["ffn_norm"], cfg)
        y = y + common.apply_mlp(h3, lp["mlp"], cfg)
        return y, (kh.astype(cache_dtype), vh.astype(cache_dtype), q_tail)

    x, (k_all, v_all, q_tails) = layer_scan(
        body, x, (params["dec_layers"], ck, cv))
    return x[:, -1], k_all, v_all, q_tails, ck, cv


@functools.partial(jax.jit, static_argnames=("cfg",))
def _head(params: dict, x_last: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = common.apply_norm(x_last, params["final_norm"], cfg)
    return x @ params["embed"].T


def _finalize_kv(params, k, v, pos, length, q_tails, cfg: ArchConfig,
                 policy: PolicyConfig, *, capacity: int, w_eff: int,
                 k_extent: int, cur_pos, batch: int,
                 k_scale=None, v_scale=None):
    from repro.models import chunked
    nominal = min(policy.nominal_budget, capacity)
    return chunked.finalize_pipeline(
        k, v, pos, length, q_tails,
        jnp.full((cfg.n_layers,), chunked.GLOBAL_WINDOW, jnp.int32),
        cur_pos,
        jnp.full((cfg.n_layers, batch), nominal, jnp.int32),
        policy=policy, capacity=capacity, w_eff=w_eff, k_extent=k_extent,
        softcap=None, scale=cfg.d_head ** -0.5, allocate=False,
        evict_cap=False, k_scale=k_scale, v_scale=v_scale)


def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
            policy: PolicyConfig, *, enc_frames: jax.Array,
            capacity: int | None = None, cache_dtype=jnp.float32, **_):
    from repro.models import chunked
    B, S = tokens.shape
    C = capacity or policy.capacity
    x_last, k_all, v_all, q_tails, ck, cv = _prefill_compute(
        params, tokens, cfg, policy, enc_frames=enc_frames,
        cache_dtype=cache_dtype)
    logits = _head(params, x_last, cfg)
    k_extent = chunked.next_pow2(S)
    eb = max(C, k_extent)
    pos = jnp.broadcast_to(
        jnp.where(jnp.arange(eb) < S, jnp.arange(eb), -1).astype(jnp.int32),
        (cfg.n_layers, B, eb))
    kv = _finalize_kv(
        params, chunked.pad_to_extent(k_all, eb, axis=3),
        chunked.pad_to_extent(v_all, eb, axis=3), pos,
        jnp.full((cfg.n_layers, B), S, jnp.int32), q_tails, cfg, policy,
        capacity=C, w_eff=min(policy.obs_window, S), k_extent=k_extent,
        cur_pos=jnp.asarray(S - 1, jnp.int32), batch=B)
    return logits, {"kv": kv, "cross_k": ck, "cross_v": cv}


# --------------------------------------------------------------------------
# Chunked prefill (DESIGN.md §Prefill). The encoder runs once at init (the
# cross-attention K/V are static); only the decoder self-attention streams
# through the working buffer.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "policy", "chunk_max",
                                             "capacity", "cache_dtype"))
def prefill_chunk_init(params: dict, tokens: jax.Array, cfg: ArchConfig,
                       policy: PolicyConfig, *, chunk_max: int,
                       capacity: int | None = None,
                       cache_dtype=jnp.float32,
                       enc_frames: jax.Array | None = None, **_) -> dict:
    from repro.models import chunked
    B = tokens.shape[0]
    C = capacity or policy.capacity
    enc_out = encode(params, enc_frames, cfg)
    ck, cv = _cross_kv(params, enc_out, cfg, cache_dtype)
    nominal = min(policy.nominal_budget, C)
    return {
        "buf": chunked.init_buffer(
            n_layers=cfg.n_layers, batch=B, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, buf_capacity=C + chunk_max,
            budgets0=jnp.full((cfg.n_layers, B), nominal, jnp.int32),
            dtype=cache_dtype, kv_format=policy.kv_format),
        "q_tail": chunked.init_q_tail(
            n_layers=cfg.n_layers, batch=B, n_heads=cfg.n_heads,
            d_head=cfg.d_head, obs_window=policy.obs_window),
        "extra": {"cross_k": ck, "cross_v": cv},
        "x_last": jnp.zeros((B, cfg.d_model), jnp.float32),
        "done": jnp.zeros((), jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "n",
                                             "capacity", "compress",
                                             "contiguous_offset"),
                   donate_argnames=("carry",))
def prefill_chunk(params: dict, carry: dict, tokens: jax.Array,
                  cfg: ArchConfig, policy: PolicyConfig, *, n: int,
                  capacity: int | None = None, compress: bool = False,
                  contiguous_offset: int | None = None) -> dict:
    import dataclasses as _dc

    from repro.models import chunked
    del n
    C = capacity or policy.capacity
    buf, q_tail, done = carry["buf"], carry["q_tail"], carry["done"]
    ck, cv = carry["extra"]["cross_k"], carry["extra"]["cross_v"]
    B, nn = tokens.shape
    if compress and policy.kind == LETHE:
        buf = _dc.replace(buf, budget=chunked.alloc_budgets(
            buf.sparsity, policy, C))
    pos_emb = jax.lax.dynamic_slice_in_dim(
        params["pos_embed"], jnp.asarray(done, jnp.int32), nn, axis=0)
    x = params["embed"][tokens] + pos_emb

    def body(xc, xs):
        lp, lay, qt, ck_l, cv_l = xs
        h = common.apply_norm(xc, lp["norm"], cfg)
        q, k, v = attention.project_qkv(h, lp["attn"], cfg)
        qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
        attn_raw, lay = chunked.attend_chunk_layer(
            lay, qh, kh, vh, done, policy=policy, window=None,
            softcap=None, scale=cfg.d_head ** -0.5, capacity=C,
            compress=compress, contiguous_offset=contiguous_offset)
        out = jnp.swapaxes(attn_raw, 1, 2).reshape(B, nn, -1) \
            @ lp["attn"]["wo"]
        y = xc + out
        h2 = common.apply_norm(y, lp["xnorm"], cfg)
        y = y + _cross_attend_full(h2, lp, ck_l, cv_l, cfg)
        h3 = common.apply_norm(y, lp["ffn_norm"], cfg)
        y = y + common.apply_mlp(h3, lp["mlp"], cfg)
        qt = chunked.roll_q_tail(qt, qh)
        return y, (lay, qt)

    x, (new_buf, new_tail) = layer_scan(
        body, x, (params["dec_layers"], buf, q_tail, ck, cv))
    return {"buf": new_buf, "q_tail": new_tail, "extra": carry["extra"],
            "x_last": x[:, -1].astype(jnp.float32),
            "done": jnp.asarray(done, jnp.int32) + nn}


def prefill_finalize(params: dict, carry: dict, cfg: ArchConfig,
                     policy: PolicyConfig, *, w_eff: int, k_extent: int,
                     capacity: int | None = None) -> tuple[jax.Array, dict]:
    from repro.models import chunked
    C = capacity or policy.capacity
    B = carry["x_last"].shape[0]
    logits = _head(params, carry["x_last"].astype(jnp.float32), cfg)
    k_e, v_e, pos_e, length, ks_e, vs_e = chunked.finalize_inputs(
        carry["buf"], capacity=C, k_extent=k_extent)
    kv = _finalize_kv(
        params, k_e, v_e, pos_e, length, carry["q_tail"], cfg, policy,
        capacity=C, w_eff=w_eff, k_extent=k_extent,
        cur_pos=jnp.asarray(carry["done"], jnp.int32) - 1, batch=B,
        k_scale=ks_e, v_scale=vs_e)
    return logits, {"kv": kv, "cross_k": carry["extra"]["cross_k"],
                    "cross_v": carry["extra"]["cross_v"]}


@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("state",))
def decode_step(params: dict, state: dict, token: jax.Array, cur_pos,
                cfg: ArchConfig, policy: PolicyConfig, **_):
    # ``state`` is donated: the KV cache updates in place and the static
    # cross-attention K/V alias straight through to the output.
    from repro.kernels import ops
    kv, ck, cv = state["kv"], state["cross_k"], state["cross_v"]
    B = token.shape[0]
    # cur_pos may be scalar or [B] (continuous batching: per-slot positions)
    pos_emb = params["pos_embed"][jnp.asarray(cur_pos, jnp.int32)]
    x = params["embed"][token] + pos_emb

    S_enc = ck.shape[-2]
    enc_pos = jnp.broadcast_to(jnp.arange(S_enc, dtype=jnp.int32),
                               (B, S_enc))

    def body(carry, xs):
        lp, lay, ck_l, cv_l = xs
        h = common.apply_norm(carry, lp["norm"], cfg)
        attn_out, lay = attention.decode_attend(
            h, lp["attn"], lay, cur_pos, cfg, policy)
        y = carry + attn_out
        # cross attention (static cache, no pruning)
        h2 = common.apply_norm(y, lp["xnorm"], cfg)
        q = (h2 @ lp["xattn"]["wq"]).reshape(B, cfg.n_heads, cfg.d_head)
        xout, _ = ops.decode_attention(
            q, ck_l, cv_l, enc_pos, jnp.asarray(S_enc, jnp.int32),
            scale=cfg.d_head ** -0.5)
        y = y + xout.reshape(B, -1) @ lp["xattn"]["wo"]
        h3 = common.apply_norm(y, lp["ffn_norm"], cfg)
        y = y + common.apply_mlp(h3, lp["mlp"], cfg)
        return y, lay

    x, new_kv = layer_scan(body, x, (params["dec_layers"], kv, ck, cv))
    x = common.apply_norm(x, params["final_norm"], cfg)
    logits = x @ params["embed"].T
    return logits, {"kv": new_kv, "cross_k": ck, "cross_v": cv}


def init_decode_state(cfg: ArchConfig, policy: PolicyConfig, batch: int,
                      dtype=jnp.float32, enc_len: int | None = None) -> dict:
    kv = cache_lib.init_cache(
        n_layers=cfg.n_layers, batch=batch, n_kv_heads=cfg.n_kv_heads,
        capacity=policy.capacity, d_head=cfg.d_head, policy=policy,
        dtype=dtype)
    S_enc = enc_len or cfg.encoder_seq_len
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, S_enc, cfg.d_head)
    return {"kv": kv, "cross_k": jnp.zeros(shape, dtype),
            "cross_v": jnp.zeros(shape, dtype)}
