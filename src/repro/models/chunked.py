"""Shared chunked-prefill machinery (DESIGN.md §Prefill).

Admission prefill as a *schedulable unit of work*: instead of one monolithic
full-sequence pass, a prompt streams through fixed-budget chunks. Each chunk
runs every layer once, appending its K/V to a per-layer working buffer and
attending over (compressed prefix ∪ chunk). The working buffer is larger
than the final cache by one chunk (`buf_capacity = capacity + chunk_max`),
so a chunk always fits; when a prompt outgrows `capacity`, a prefill-phase
compression round (`pruning.compress_prefill_layer` — the same
`decide_row`/Algorithm-1 machinery as decode pruning, with the final cache
capacity as an explicit ceiling) shrinks the buffer between chunks. Prompts
up to buffer-bounded *any* length therefore admit in bounded memory.

Differential guarantee: for prompts that fit `capacity`, chunked prefill is
**bit-identical** to whole-prompt prefill — same first token, same per-layer
budgets, same RASR scores, same cache tensors. Three properties deliver it:

1. Per-token ops (norms, projections, FFN) are row-independent, so chunk
   hidden states equal the corresponding rows of the full pass bitwise.
2. Masked attention over the working buffer equals full-sequence attention:
   invalid tail slots score the same `-1e30` sentinel the causal mask uses,
   whose softmax terms underflow to exact zeros — the reductions agree
   bit-for-bit with the shorter full-pass reductions.
3. The statistics/fill/budget/prune tail runs as ONE compiled program —
   `finalize_pipeline` below — invoked by BOTH the whole-prompt `prefill`
   and chunked finalize with canonically-shaped inputs (pow2-bucketed key
   extent, fixed-width right-aligned query tail). Sharing the *program*
   (not just the math) matters: the same reduction expressed inside two
   different XLA programs can fuse differently and drift by an ulp.

For compressed prompts (S > capacity) the mid-prefill eviction score is the
Eq. 5 EMA unrolled over the chunk (per-query-row γ-decayed attention
column-sums), and the surviving tokens' RASR scores are re-seeded at
finalize from the observation window over the survivors.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import cache as cache_lib
from repro.core import rasr
from repro.core import sparsity as sparsity_lib
from repro.core.policy import LETHE, PolicyConfig
from repro.kernels import ops

GLOBAL_WINDOW = 1 << 30     # no-window sentinel (same as the decode kernel)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(n - 1, 1).bit_length() if n > 1 else 1


def finalize_extent(s_total: int, capacity: int) -> int:
    """Static key extent for finalize observation statistics, bucketed to a
    power of two so a refill wave over many distinct prompt lengths shares
    O(log) finalize programs. Matches the whole-prompt ``prefill``'s padded
    extent on uncompressed prompts; compressed prompts (whose survivors
    number at most ``capacity``) all share one extent."""
    return next_pow2(min(s_total, capacity) if s_total > capacity
                     else s_total)


def pad_to_extent(x: jax.Array, extent: int, axis: int, fill=0) -> jax.Array:
    """Slice or zero/``fill``-pad ``x`` along ``axis`` to a static extent."""
    n = x.shape[axis]
    if n == extent:
        return x
    if n > extent:
        return jax.lax.slice_in_dim(x, 0, extent, axis=axis)
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, extent - n)
    return jnp.pad(x, pad, constant_values=fill)


# --------------------------------------------------------------------------
# Carry construction
# --------------------------------------------------------------------------

def init_buffer(*, n_layers: int, batch: int, n_kv_heads: int, d_head: int,
                buf_capacity: int, budgets0: jax.Array,
                dtype=jnp.float32, kv_format: str = "bf16"
                ) -> cache_lib.KVCache:
    """Empty chunked-prefill working buffer ([L, B, Hkv, Cbuf, Dh]).

    ``budgets0`` [L, B]: the policy's static budget schedule — used by
    prefill-phase compression until (LETHE) live sparsity estimates exist.
    ``evict_at`` is parked at the buffer capacity: the Algorithm-1 decode
    schedule does not run during prefill. With ``kv_format="int8"`` the
    working buffer itself is quantized: chunks quantize on append and every
    later chunk attends over the int8 prefix — long-prompt admission is
    bytes-bounded by the *quantized* buffer size.
    """
    shape = (n_layers, batch, n_kv_heads, buf_capacity, d_head)
    k, v, k_scale, v_scale = cache_lib.init_kv_payload(
        shape, kv_format=kv_format, dtype=dtype)
    return cache_lib.KVCache(
        k=k, v=v,
        pos=jnp.full((n_layers, batch, buf_capacity), -1, jnp.int32),
        score=jnp.zeros((n_layers, batch, buf_capacity), jnp.float32),
        length=jnp.zeros((n_layers, batch), jnp.int32),
        budget=budgets0.astype(jnp.int32),
        evict_at=jnp.full((n_layers, batch), buf_capacity, jnp.int32),
        sparsity=jnp.zeros((n_layers, batch), jnp.float32),
        k_scale=k_scale, v_scale=v_scale,
    )


def resume_buffer(rows: cache_lib.KVCache, *,
                  buf_capacity: int) -> cache_lib.KVCache:
    """Restored prefix rows (a finalized decode state, capacity C) -> a
    chunked-prefill working buffer (capacity ``buf_capacity`` = C +
    chunk_max) — the prefix-reuse partial-hit entry point: suffix chunks
    append after the restored tokens and attend over them exactly as they
    would over a cold buffer holding the same K/V.

    Everything the snapshot carries survives verbatim: K/V payload (and
    dequant scales, padded with unit scales so empty tail slots round-trip
    to zeros), positions (tail padded -1 = invalid), RASR scores, length,
    budgets and sparsity. ``evict_at`` is parked at the buffer capacity —
    the Algorithm-1 decode schedule does not run during prefill; the
    compression round and the finalize prune re-derive it.
    """
    L, B = rows.length.shape
    ks = vs = None
    if rows.quantized:
        ks = pad_to_extent(jnp.asarray(rows.k_scale), buf_capacity,
                           axis=3, fill=1)
        vs = pad_to_extent(jnp.asarray(rows.v_scale), buf_capacity,
                           axis=3, fill=1)
    return cache_lib.KVCache(
        k=pad_to_extent(jnp.asarray(rows.k), buf_capacity, axis=3),
        v=pad_to_extent(jnp.asarray(rows.v), buf_capacity, axis=3),
        pos=pad_to_extent(jnp.asarray(rows.pos), buf_capacity, axis=2,
                          fill=-1),
        score=pad_to_extent(jnp.asarray(rows.score), buf_capacity, axis=2),
        length=jnp.asarray(rows.length),
        budget=jnp.asarray(rows.budget),
        evict_at=jnp.full((L, B), buf_capacity, jnp.int32),
        sparsity=jnp.asarray(rows.sparsity),
        k_scale=ks, v_scale=vs,
    )


def init_q_tail(*, n_layers: int, batch: int, n_heads: int, d_head: int,
                obs_window: int) -> jax.Array:
    """Zero rolling query-tail [L, B, Hq, W, Dh]; real queries fill from the
    right as chunks stream through (``roll_q_tail``)."""
    return jnp.zeros((n_layers, batch, n_heads, obs_window, d_head),
                     jnp.float32)


def roll_q_tail(tail: jax.Array, qh: jax.Array) -> jax.Array:
    """Shift a chunk's post-RoPE queries ([B, Hq, n, Dh]) into the rolling
    tail ([B, Hq, W, Dh]): the last W of (tail ++ chunk)."""
    W = tail.shape[2]
    return jnp.concatenate([tail, qh.astype(tail.dtype)], axis=2)[:, :, -W:]


def alloc_budgets(sparsity: jax.Array, policy: PolicyConfig,
                  capacity: int) -> jax.Array:
    """The Lethe spatial allocation with the decode-path floor expression
    (one spelling, shared by chunk compression and finalize)."""
    nominal = min(policy.nominal_budget, capacity)
    return sparsity_lib.allocate_budgets_batched(
        sparsity, capacity=capacity, nominal=nominal,
        min_budget=max(policy.sink_len + policy.recent_len + 2,
                       int(policy.min_budget_ratio * nominal)),
        sink_len=policy.sink_len, recent_len=policy.recent_len)


# --------------------------------------------------------------------------
# Per-layer chunk step
# --------------------------------------------------------------------------

def attend_chunk_layer(lay: cache_lib.KVCache, qh: jax.Array, kh: jax.Array,
                       vh: jax.Array, q_start, *, policy: PolicyConfig,
                       window, softcap, scale: float, capacity: int,
                       compress: bool,
                       contiguous_offset: int | None = None
                       ) -> tuple[jax.Array, cache_lib.KVCache]:
    """One layer's chunk step: append the chunk's K/V to the working buffer,
    attend the chunk queries over it, and (when ``compress`` — prompts
    longer than ``capacity``) update the mid-prefill eviction scores and run
    the compression round.

    qh/kh/vh: [B, Hq|Hkv, n, Dh] post-RoPE; ``q_start`` traced scalar.
    Returns (attn out [B, Hq, n, Dh], buffer').
    """
    n = qh.shape[2]
    pos_new = jnp.arange(n, dtype=jnp.int32) + jnp.asarray(q_start,
                                                           jnp.int32)
    lay = cache_lib.append_chunk(lay, kh, vh, pos_new)
    out = ops.chunk_attention(
        qh, lay.k, lay.v, lay.pos, q_start, window=window, softcap=softcap,
        scale=scale, contiguous_offset=contiguous_offset,
        k_scale=lay.k_scale, v_scale=lay.v_scale)

    if compress:
        # Eq. 5 unrolled over the chunk: each query row i contributes its
        # attention column-sums decayed by γ^(n-1-i), on top of γ^n times
        # the pre-chunk score — the exact arithmetic a token-at-a-time
        # decode of the chunk would produce.
        colsums, probs = ops.obs_colsums(
            qh, lay.k, win_start=q_start, window=window, softcap=softcap,
            scale=scale, k_pos=lay.pos, k_scale=lay.k_scale)
        del colsums
        gam = jnp.float32(policy.gamma)
        w_rows = gam ** jnp.arange(n - 1, -1, -1, dtype=jnp.float32)
        weighted = jnp.einsum("bhwc,w->bc", probs.astype(jnp.float32),
                              w_rows)
        valid = cache_lib.valid_mask(lay.pos)
        new_score = jnp.where(valid, gam ** n * lay.score + weighted, 0.0)
        obs = sparsity_lib.row_sparsity_from_probs(
            probs, where=valid[:, None, None, :],
            n_valid=jnp.maximum(lay.length, 2)[:, None, None])
        new_spars = sparsity_lib.update_sparsity_ema(
            lay.sparsity, obs, policy.sparsity_ema)
        lay = dataclasses.replace(lay, score=new_score, sparsity=new_spars)

        from repro.core import pruning
        cur = jnp.asarray(q_start, jnp.int32) + n - 1
        lay = pruning.compress_prefill_layer(
            lay, cur, policy=policy, max_keep=capacity, window=window)
    return out, lay


# --------------------------------------------------------------------------
# Finalize: THE shared prefill tail pipeline.
#
# Observation-window RASR scores + Hoyer sparsity over the retained keys,
# spatial budget allocation, top-capacity fill, forced prune round — as ONE
# top-level jitted program invoked by both the whole-prompt ``prefill`` and
# chunked ``prefill_finalize`` with canonically-shaped inputs. Sharing the
# compiled program (not just the math) is what makes the two admission
# paths bit-identical: the same statistics expressed inside two different
# programs can fuse differently under XLA and drift by an ulp.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "policy", "capacity", "w_eff", "k_extent", "softcap", "scale",
    "allocate", "evict_cap"))
def finalize_pipeline(k: jax.Array, v: jax.Array, pos: jax.Array,
                      length: jax.Array, q_tail: jax.Array,
                      windows: jax.Array, cur_pos, budgets_default:
                      jax.Array, *, policy: PolicyConfig, capacity: int,
                      w_eff: int, k_extent: int, softcap, scale: float,
                      allocate: bool, evict_cap: bool,
                      k_scale: jax.Array | None = None,
                      v_scale: jax.Array | None = None
                      ) -> cache_lib.KVCache:
    """Slotted prefill working set -> initialised decode cache.

    k/v [L, B, Hkv, Eb, Dh], pos [L, B, Eb], length [L, B] with the
    canonical buffer extent Eb = max(capacity, k_extent); q_tail
    [L, B, Hq, W, Dh] holds the last ``w_eff`` post-RoPE queries
    right-aligned (zeros to the left for prompts shorter than W);
    ``windows`` [L] per-layer attention windows (GLOBAL_WINDOW sentinel =
    unwindowed); ``cur_pos``: last prompt position (traced);
    ``budgets_default`` [L, B]: the schedule used when ``allocate`` is off
    (non-LETHE policies, or families that skip prefill allocation).

    ``k_extent``: the static, power-of-two bucketed (``finalize_extent``)
    key extent the statistics reduce over — it must cover every live slot.
    Bucketing it is what lets a refill wave over many distinct prompt
    lengths share O(log) compiled pipelines. ``evict_cap``: clamp evict_at
    to capacity (transformer-family spelling); otherwise evict_at=budgets.

    Quantized mode (``policy.kv_format == "int8"``): when ``k_scale`` /
    ``v_scale`` are given the working set is already int8 (chunked prefill
    quantized on append) and the statistics dequantise through the kernels;
    when they are None (whole-prompt prefill hands in the dense transient
    K/V) the statistics run on exact values and the payload is quantized
    HERE — the quantize-on-write point of the fill path. Quantization is
    per-token, so it commutes with the top-C gather below.
    """
    L, B = length.shape
    cur = jnp.asarray(cur_pos, jnp.int32)
    win_start = cur - (w_eff - 1)

    def layer_stats(k_l, pos_l, len_l, qt, w, ks_l):
        q_win = qt[:, :, -w_eff:]
        k_e = k_l[..., :k_extent, :]
        pos_e = pos_l[..., :k_extent]
        ks_e = None if ks_l is None else ks_l[..., :k_extent]
        colsums, probs = ops.obs_colsums(
            q_win, k_e, win_start=win_start, window=w, softcap=softcap,
            scale=scale, k_pos=pos_e, k_scale=ks_e)
        scores = pad_to_extent(rasr.prefill_scores(colsums, w_eff),
                               pos_l.shape[-1], axis=1)
        valid = pos_e >= 0
        spars = sparsity_lib.row_sparsity_from_probs(
            probs, where=valid[:, None, None, :],
            n_valid=jnp.maximum(len_l, 2)[:, None, None])
        return scores, spars

    scores_all, spars_all = jax.vmap(layer_stats)(k, pos, length, q_tail,
                                                  windows, k_scale)

    if allocate and policy.kind == LETHE:
        budgets = alloc_budgets(spars_all, policy, capacity)
    else:
        budgets = budgets_default.astype(jnp.int32)

    if getattr(policy, "quantized", False) and k_scale is None:
        # whole-prompt path: quantize-on-fill from the exact dense K/V
        k, k_scale = cache_lib.quantize_kv(k)
        v, v_scale = cache_lib.quantize_kv(v)

    fill = jax.vmap(
        lambda k_l, v_l, p_l, s_l, n_l, ks_l, vs_l:
        cache_lib.fill_from_prefill_slotted(
            k_l, v_l, p_l, s_l, n_l, capacity=capacity,
            k_scale=ks_l, v_scale=vs_l))
    k_c, v_c, pos_c, score_c, len_c, ks_c, vs_c = fill(
        k, v, pos, scores_all, length, k_scale, v_scale)
    cache = cache_lib.KVCache(
        k=k_c, v=v_c, pos=pos_c, score=score_c, length=len_c,
        budget=budgets,
        evict_at=(jnp.minimum(budgets, capacity).astype(jnp.int32)
                  if evict_cap else budgets),
        sparsity=spars_all, k_scale=ks_c, v_scale=vs_c)

    if policy.prunes:
        from repro.core import pruning
        cache = jax.vmap(
            lambda lay, w: pruning.prune_layer(
                lay, cur, policy=policy, window=w, force=True)
        )(cache, windows)
    return cache


def finalize_inputs(buf: cache_lib.KVCache, *, capacity: int,
                    k_extent: int):
    """Pad/slice a chunked working buffer to the pipeline's canonical
    extent Eb = max(capacity, k_extent) (pure data movement, exact).
    Returns (k, v, pos, length, k_scale, v_scale) — scales None unless the
    buffer is quantized."""
    eb = max(capacity, k_extent)
    ks = vs = None
    if buf.quantized:
        ks = pad_to_extent(buf.k_scale, eb, axis=3, fill=1)
        vs = pad_to_extent(buf.v_scale, eb, axis=3, fill=1)
    return (pad_to_extent(buf.k, eb, axis=3),
            pad_to_extent(buf.v, eb, axis=3),
            pad_to_extent(buf.pos, eb, axis=2, fill=-1),
            buf.length, ks, vs)
