"""Shared model building blocks (pure JAX, no flax)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


# --------------------------------------------------------------------------
# Initialisers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype=jnp.float32, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def apply_norm(x, norm_params: dict, cfg: ArchConfig):
    if cfg.norm_style == "layernorm":
        return layernorm(x, norm_params["scale"], norm_params["bias"],
                         cfg.rms_eps)
    return rmsnorm(x, norm_params["scale"], cfg.rms_eps)


def init_norm(key, d: int, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    if cfg.norm_style == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.zeros((d,), dtype)}  # rmsnorm stores (scale - 1)


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------

def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu_sq":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    """[d_head//2] inverse frequencies."""
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` [..., S, Dh] at ``positions`` [..., S] (broadcastable).

    Split-halves convention: pairs are (x[..., :H], x[..., H:])."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                     # [H]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, H]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. ``x``: [..., S, Dh]; ``positions3``:
    [3, ..., S] — separate temporal/height/width position streams. Frequency
    bands are partitioned by ``sections`` (sums to Dh//2): band j uses the
    position stream of its section."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)                     # [half]
    # section id per frequency band
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections),
        total_repeat_length=half)                     # [half]
    # pick position stream per band: pos3 [3, ..., S] -> [..., S, half]
    pos = jnp.take(positions3, sec_id, axis=0)        # [half, ..., S]
    pos = jnp.moveaxis(pos, 0, -1)                    # [..., S, half]
    ang = pos.astype(jnp.float32) * freqs             # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings [length, dim]."""
    half = dim // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(length)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), dtype),
         "w_down": dense_init(ks[1], (f, d), dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], (d, f), dtype)
    return p


def apply_mlp(x: jax.Array, p: dict, cfg: ArchConfig) -> jax.Array:
    up = x @ p["w_up"]
    if cfg.gated_mlp:
        h = activation(x @ p["w_gate"], cfg.act) * up
    else:
        h = activation(up, cfg.act)
    return h @ p["w_down"]


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def unembed(x: jax.Array, params: dict, cfg: ArchConfig) -> jax.Array:
    """Final norm + output projection + final softcap. x [..., D] -> logits."""
    x = apply_norm(x, params["final_norm"], cfg)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["unembed"]
    return softcap(logits, cfg.final_logit_softcap)


def embed_tokens(tokens: jax.Array, params: dict, cfg: ArchConfig) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.emb_scale_by_sqrt_dim:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x
