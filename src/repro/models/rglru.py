"""RecurrentGemma / Griffin hybrid — RG-LRU recurrent blocks interleaved with
local (sliding-window) attention, pattern (rglru, rglru, local_attn)
[arXiv:2402.19427].

TPU adaptation: the RG-LRU linear recurrence h_t = a_t·h_{t-1} + b_t is run
with ``jax.lax.associative_scan`` for training/prefill (parallel, log-depth —
the TPU-native form) and as a single fused step during decode.

Lethe applicability: only the 1-in-3 local-attention layers own a KV cache,
and that cache is already window-bounded; Lethe can shrink it further below
the window (supported here — the attention layers use the shared slotted
cache machinery), but the headroom is bounded by construction (DESIGN.md).

Layers are heterogeneous, so this model uses a Python loop (26 layers) with
per-kind parameter lists instead of a layer scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import LOCAL_ATTN, RGLRU, ArchConfig
from repro.core import cache as cache_lib
from repro.core.policy import PolicyConfig
from repro.models import attention, common

_C_CONST = 8.0


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def _init_rglru_block(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 8)
    return {
        "norm": common.init_norm(ks[0], d, cfg, dtype),
        "w_y": common.dense_init(ks[1], (d, w), dtype),
        "w_gate": common.dense_init(ks[2], (d, w), dtype),
        "conv_w": common.dense_init(ks[3], (cfg.conv_width, w), dtype,
                                    scale=0.3),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": common.dense_init(ks[4], (w, w), dtype),
        "ba": jnp.zeros((w,), dtype),
        "wx": common.dense_init(ks[5], (w, w), dtype),
        "bx": jnp.zeros((w,), dtype),
        # softplus(lambda) init so decay a^c is in a useful range
        "lam": jnp.asarray(
            jnp.linspace(0.3, 1.5, w), dtype),
        "w_out": common.dense_init(ks[6], (w, d), dtype),
        "ffn_norm": common.init_norm(ks[7], d, cfg, dtype),
    }


def _init_attn_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm": common.init_norm(ks[0], cfg.d_model, cfg, dtype),
        "attn": attention.init_attention(ks[1], cfg, dtype),
        "ffn_norm": common.init_norm(ks[2], cfg.d_model, cfg, dtype),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 4)
    layers = []
    for i, kind in enumerate(cfg.layer_kinds):
        if kind == RGLRU:
            lp = _init_rglru_block(ks[i], cfg, dtype)
        else:
            lp = _init_attn_block(ks[i], cfg, dtype)
        mlp_key = jax.random.fold_in(ks[i], 999)
        lp["mlp"] = common.init_mlp(mlp_key, cfg.d_model, cfg.d_ff, cfg,
                                    dtype)
        layers.append(lp)
    return {
        "embed": common.embed_init(ks[-3], (cfg.vocab_size, cfg.d_model),
                                   dtype),
        "layers": layers,
        "final_norm": common.init_norm(ks[-2], cfg.d_model, cfg, dtype),
        "unembed": common.dense_init(ks[-1], (cfg.d_model, cfg.vocab_size),
                                     dtype),
    }


# --------------------------------------------------------------------------
# RG-LRU pieces
# --------------------------------------------------------------------------

def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                   prev: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x [B, S, W]; w [cw, W]. ``prev`` [B, cw-1, W]
    supplies history for decode (S == 1)."""
    cw = w.shape[0]
    if prev is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = prev
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+cw-1, W]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    return out + b


def _rglru_gates(x: jax.Array, p: dict):
    """a_t (decay) and gated input b_t for the linear recurrence."""
    r = jax.nn.sigmoid(x @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(x @ p["wx"] + p["bx"])
    log_a = -_C_CONST * jax.nn.softplus(p["lam"]) * r    # [..., W]
    a = jnp.exp(log_a.astype(jnp.float32))
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9, 1.0)) * (i * x).astype(
        jnp.float32)
    return a, b


def _rglru_seq(x: jax.Array, p: dict, h0: jax.Array) -> tuple[jax.Array,
                                                              jax.Array]:
    """Linear recurrence over a sequence via associative scan.
    x [B, S, W]; h0 [B, W] initial state. Returns (y [B,S,W], h_last)."""
    a, b = _rglru_gates(x, p)                        # [B, S, W] each
    # fold h0 into the first step: b_0' = a_0*h0 + b_0
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


def _rglru_block_seq(x: jax.Array, lp: dict, cfg: ArchConfig,
                     state: dict | None):
    """Full recurrent block over a sequence. x [B, S, D]."""
    h = common.apply_norm(x, lp["norm"], cfg)
    y = h @ lp["w_y"]
    gate = jax.nn.gelu(h @ lp["w_gate"], approximate=True)
    prev = None if state is None else state["conv"]
    yc = _conv1d_causal(y, lp["conv_w"], lp["conv_b"], prev)
    h0 = (jnp.zeros((x.shape[0], y.shape[-1]), jnp.float32)
          if state is None else state["h"])
    yr, h_last = _rglru_seq(yc, lp, h0)
    out = (yr * gate) @ lp["w_out"]
    x = x + out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    x = x + common.apply_mlp(h2, lp["mlp"], cfg)
    cw = cfg.conv_width
    new_state = {"h": h_last,
                 "conv": y[:, -(cw - 1):] if y.shape[1] >= cw - 1 else
                 jnp.concatenate([jnp.zeros((y.shape[0], cw - 1 - y.shape[1],
                                             y.shape[2]), y.dtype), y], 1)}
    return x, new_state


def _rglru_block_step(x: jax.Array, lp: dict, cfg: ArchConfig, state: dict):
    """Single decode step. x [B, D]."""
    h = common.apply_norm(x, lp["norm"], cfg)
    y = h @ lp["w_y"]                                 # [B, W]
    gate = jax.nn.gelu(h @ lp["w_gate"], approximate=True)
    # conv with ring history
    hist = state["conv"]                              # [B, cw-1, W]
    cw = cfg.conv_width
    xp = jnp.concatenate([hist, y[:, None, :]], axis=1)
    yc = sum(xp[:, i] * lp["conv_w"][i] for i in range(cw)) + lp["conv_b"]
    a, b = _rglru_gates(yc, lp)
    h_new = a * state["h"] + b
    out = (h_new.astype(x.dtype) * gate) @ lp["w_out"]
    x = x + out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    x = x + common.apply_mlp(h2, lp["mlp"], cfg)
    return x, {"h": h_new, "conv": xp[:, 1:]}


def _attn_block_seq(x, lp, cfg, window):
    h = common.apply_norm(x, lp["norm"], cfg)
    out = attention.attend_full(h, lp["attn"], cfg, window=window)
    x = x + out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    return x + common.apply_mlp(h2, lp["mlp"], cfg)


# --------------------------------------------------------------------------
# Model entry points
# --------------------------------------------------------------------------

def _attn_layer_ids(cfg: ArchConfig) -> list[int]:
    return [i for i, k in enumerate(cfg.layer_kinds) if k == LOCAL_ATTN]


@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params, tokens, cfg: ArchConfig, **_):
    x = common.embed_tokens(tokens, params, cfg)
    for i, kind in enumerate(cfg.layer_kinds):
        lp = params["layers"][i]
        if kind == RGLRU:
            x, _ = _rglru_block_seq(x, lp, cfg, None)
        else:
            x = _attn_block_seq(x, lp, cfg, cfg.sliding_window)
    return common.unembed(x, params, cfg), jnp.float32(0.0)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "capacity",
                                             "cache_dtype"))
def prefill(params, tokens, cfg: ArchConfig, policy: PolicyConfig, *,
            capacity=None, cache_dtype=jnp.float32, **_):
    B, S = tokens.shape
    C = capacity or policy.capacity
    attn_ids = _attn_layer_ids(cfg)
    x = common.embed_tokens(tokens, params, cfg)
    rec_states, kv_layers = [], []
    for i, kind in enumerate(cfg.layer_kinds):
        lp = params["layers"][i]
        if kind == RGLRU:
            x, st = _rglru_block_seq(x, lp, cfg, None)
            rec_states.append(st)
        else:
            h = common.apply_norm(x, lp["norm"], cfg)
            q, k, v = attention.project_qkv(h, lp["attn"], cfg)
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            q, k = attention._rope(q, k, positions, cfg)
            qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
            from repro.kernels import ops
            attn_raw = ops.prefill_attention(
                qh, kh, vh, causal=True, window=cfg.sliding_window,
                scale=cfg.d_head ** -0.5)
            out = jnp.swapaxes(attn_raw, 1, 2).reshape(B, S, -1) \
                @ lp["attn"]["wo"]
            scores, spars = attention.prefill_stats(
                qh, kh, cfg, policy, window=cfg.sliding_window)
            x = x + out
            h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
            x = x + common.apply_mlp(h2, lp["mlp"], cfg)
            kv_layers.append((kh.astype(cache_dtype), vh.astype(cache_dtype),
                              scores, spars))
    logits = common.unembed(x[:, -1], params, cfg)

    # Build the (attention-layers-only) slotted cache.
    k_all = jnp.stack([t[0] for t in kv_layers])
    v_all = jnp.stack([t[1] for t in kv_layers])
    sc_all = jnp.stack([t[2] for t in kv_layers])
    sp_all = jnp.stack([t[3] for t in kv_layers])
    fill = jax.vmap(lambda k, v, s: cache_lib.fill_from_prefill(
        k=k, v=v, scores=s, capacity=C))
    k_c, v_c, pos_c, score_c, len_c = fill(k_all, v_all, sc_all)
    nominal = min(policy.nominal_budget, C)
    budgets = jnp.full((len(attn_ids), B), nominal, jnp.int32)
    kv = cache_lib.KVCache(
        k=k_c, v=v_c, pos=pos_c, score=score_c, length=len_c,
        budget=budgets, evict_at=budgets, sparsity=sp_all)
    if policy.prunes:
        from repro.core import pruning
        cur = jnp.asarray(S - 1, jnp.int32)
        kv = jax.vmap(lambda lay: pruning.prune_layer(
            lay, cur, policy=policy,
            window=jnp.asarray(cfg.sliding_window, jnp.int32),
            force=True))(kv)
    state = {"rec": jax.tree.map(lambda *xs: jnp.stack(xs), *rec_states),
             "kv": kv}
    return logits, state


@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("state",))
def decode_step(params, state, token, cur_pos, cfg: ArchConfig,
                policy: PolicyConfig, **_):
    # ``state`` (recurrent h/conv + the attention layers' KV cache) is
    # donated so the per-step buffers update in place.
    x = common.embed_tokens(token, params, cfg)
    kv, rec = state["kv"], state["rec"]
    new_kv_layers, new_rec_layers = [], []
    ai = ri = 0
    for i, kind in enumerate(cfg.layer_kinds):
        lp = params["layers"][i]
        if kind == RGLRU:
            st = jax.tree.map(lambda a: a[ri], rec)
            x, st2 = _rglru_block_step(x, lp, cfg, st)
            new_rec_layers.append(st2)
            ri += 1
        else:
            lay = kv.layer(ai)
            h = common.apply_norm(x, lp["norm"], cfg)
            attn_out, lay = attention.decode_attend(
                h, lp["attn"], lay, cur_pos, cfg, policy,
                window=jnp.asarray(cfg.sliding_window, jnp.int32))
            x = x + attn_out
            h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
            x = x + common.apply_mlp(h2, lp["mlp"], cfg)
            new_kv_layers.append(lay)
            ai += 1
    new_state = {
        "rec": jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec_layers),
        "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv_layers),
    }
    logits = common.unembed(x, params, cfg)
    return logits, new_state


def init_decode_state(cfg: ArchConfig, policy: PolicyConfig, batch: int,
                      dtype=jnp.float32) -> dict:
    n_attn = len(_attn_layer_ids(cfg))
    n_rec = cfg.n_layers - n_attn
    w = cfg.lru_width or cfg.d_model
    kv = cache_lib.init_cache(
        n_layers=n_attn, batch=batch, n_kv_heads=cfg.n_kv_heads,
        capacity=policy.capacity, d_head=cfg.d_head, policy=policy,
        dtype=dtype)
    rec = {"h": jnp.zeros((n_rec, batch, w), jnp.float32),
           "conv": jnp.zeros((n_rec, batch, cfg.conv_width - 1, w), dtype)}
    return {"rec": rec, "kv": kv}
