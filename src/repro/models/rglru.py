"""RecurrentGemma / Griffin hybrid — RG-LRU recurrent blocks interleaved with
local (sliding-window) attention, pattern (rglru, rglru, local_attn)
[arXiv:2402.19427].

TPU adaptation: the RG-LRU linear recurrence h_t = a_t·h_{t-1} + b_t is run
with ``jax.lax.associative_scan`` for training/prefill (parallel, log-depth —
the TPU-native form) and as a single fused step during decode.

Lethe applicability: only the 1-in-3 local-attention layers own a KV cache,
and that cache is already window-bounded; Lethe can shrink it further below
the window (supported here — the attention layers use the shared slotted
cache machinery), but the headroom is bounded by construction (DESIGN.md).

Layers are heterogeneous, so this model uses a Python loop (26 layers) with
per-kind parameter lists instead of a layer scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import LOCAL_ATTN, RGLRU, ArchConfig
from repro.core import cache as cache_lib
from repro.core.policy import PolicyConfig
from repro.models import attention, common

_C_CONST = 8.0


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def _init_rglru_block(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 8)
    return {
        "norm": common.init_norm(ks[0], d, cfg, dtype),
        "w_y": common.dense_init(ks[1], (d, w), dtype),
        "w_gate": common.dense_init(ks[2], (d, w), dtype),
        "conv_w": common.dense_init(ks[3], (cfg.conv_width, w), dtype,
                                    scale=0.3),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": common.dense_init(ks[4], (w, w), dtype),
        "ba": jnp.zeros((w,), dtype),
        "wx": common.dense_init(ks[5], (w, w), dtype),
        "bx": jnp.zeros((w,), dtype),
        # softplus(lambda) init so decay a^c is in a useful range
        "lam": jnp.asarray(
            jnp.linspace(0.3, 1.5, w), dtype),
        "w_out": common.dense_init(ks[6], (w, d), dtype),
        "ffn_norm": common.init_norm(ks[7], d, cfg, dtype),
    }


def _init_attn_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm": common.init_norm(ks[0], cfg.d_model, cfg, dtype),
        "attn": attention.init_attention(ks[1], cfg, dtype),
        "ffn_norm": common.init_norm(ks[2], cfg.d_model, cfg, dtype),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 4)
    layers = []
    for i, kind in enumerate(cfg.layer_kinds):
        if kind == RGLRU:
            lp = _init_rglru_block(ks[i], cfg, dtype)
        else:
            lp = _init_attn_block(ks[i], cfg, dtype)
        mlp_key = jax.random.fold_in(ks[i], 999)
        lp["mlp"] = common.init_mlp(mlp_key, cfg.d_model, cfg.d_ff, cfg,
                                    dtype)
        layers.append(lp)
    return {
        "embed": common.embed_init(ks[-3], (cfg.vocab_size, cfg.d_model),
                                   dtype),
        "layers": layers,
        "final_norm": common.init_norm(ks[-2], cfg.d_model, cfg, dtype),
        "unembed": common.dense_init(ks[-1], (cfg.d_model, cfg.vocab_size),
                                     dtype),
    }


# --------------------------------------------------------------------------
# RG-LRU pieces
# --------------------------------------------------------------------------

def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                   prev: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x [B, S, W]; w [cw, W]. ``prev`` [B, cw-1, W]
    supplies history for decode (S == 1)."""
    cw = w.shape[0]
    if prev is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = prev
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+cw-1, W]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    return out + b


def _rglru_gates(x: jax.Array, p: dict):
    """a_t (decay) and gated input b_t for the linear recurrence."""
    r = jax.nn.sigmoid(x @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(x @ p["wx"] + p["bx"])
    log_a = -_C_CONST * jax.nn.softplus(p["lam"]) * r    # [..., W]
    a = jnp.exp(log_a.astype(jnp.float32))
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9, 1.0)) * (i * x).astype(
        jnp.float32)
    return a, b


def _rglru_seq(x: jax.Array, p: dict, h0: jax.Array) -> tuple[jax.Array,
                                                              jax.Array]:
    """Linear recurrence over a sequence via associative scan.
    x [B, S, W]; h0 [B, W] initial state. Returns (y [B,S,W], h_last)."""
    a, b = _rglru_gates(x, p)                        # [B, S, W] each
    # fold h0 into the first step: b_0' = a_0*h0 + b_0
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


def _rglru_block_seq(x: jax.Array, lp: dict, cfg: ArchConfig,
                     state: dict | None):
    """Full recurrent block over a sequence. x [B, S, D]."""
    h = common.apply_norm(x, lp["norm"], cfg)
    y = h @ lp["w_y"]
    gate = jax.nn.gelu(h @ lp["w_gate"], approximate=True)
    prev = None if state is None else state["conv"]
    yc = _conv1d_causal(y, lp["conv_w"], lp["conv_b"], prev)
    h0 = (jnp.zeros((x.shape[0], y.shape[-1]), jnp.float32)
          if state is None else state["h"])
    yr, h_last = _rglru_seq(yc, lp, h0)
    out = (yr * gate) @ lp["w_out"]
    x = x + out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    x = x + common.apply_mlp(h2, lp["mlp"], cfg)
    cw = cfg.conv_width
    # Conv history for the next step/chunk: the last cw-1 *inputs including
    # any carried-in history* (a chunk shorter than the conv width must not
    # refill the window with zeros — that would desynchronise chunked
    # prefill from the whole-sequence pass).
    hist_in = (jnp.zeros((y.shape[0], cw - 1, y.shape[2]), y.dtype)
               if prev is None else prev)
    new_state = {"h": h_last,
                 "conv": jnp.concatenate([hist_in, y], 1)[:, -(cw - 1):]}
    return x, new_state


def _rglru_block_step(x: jax.Array, lp: dict, cfg: ArchConfig, state: dict):
    """Single decode step. x [B, D]."""
    h = common.apply_norm(x, lp["norm"], cfg)
    y = h @ lp["w_y"]                                 # [B, W]
    gate = jax.nn.gelu(h @ lp["w_gate"], approximate=True)
    # conv with ring history
    hist = state["conv"]                              # [B, cw-1, W]
    cw = cfg.conv_width
    xp = jnp.concatenate([hist, y[:, None, :]], axis=1)
    yc = sum(xp[:, i] * lp["conv_w"][i] for i in range(cw)) + lp["conv_b"]
    a, b = _rglru_gates(yc, lp)
    h_new = a * state["h"] + b
    out = (h_new.astype(x.dtype) * gate) @ lp["w_out"]
    x = x + out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    x = x + common.apply_mlp(h2, lp["mlp"], cfg)
    return x, {"h": h_new, "conv": xp[:, 1:]}


def _attn_block_seq(x, lp, cfg, window):
    h = common.apply_norm(x, lp["norm"], cfg)
    out = attention.attend_full(h, lp["attn"], cfg, window=window)
    x = x + out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    return x + common.apply_mlp(h2, lp["mlp"], cfg)


# --------------------------------------------------------------------------
# Model entry points
# --------------------------------------------------------------------------

def _attn_layer_ids(cfg: ArchConfig) -> list[int]:
    return [i for i, k in enumerate(cfg.layer_kinds) if k == LOCAL_ATTN]


@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params, tokens, cfg: ArchConfig, **_):
    x = common.embed_tokens(tokens, params, cfg)
    for i, kind in enumerate(cfg.layer_kinds):
        lp = params["layers"][i]
        if kind == RGLRU:
            x, _ = _rglru_block_seq(x, lp, cfg, None)
        else:
            x = _attn_block_seq(x, lp, cfg, cfg.sliding_window)
    return common.unembed(x, params, cfg), jnp.float32(0.0)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "cache_dtype"))
def _prefill_compute(params, tokens, cfg: ArchConfig, policy: PolicyConfig,
                     *, cache_dtype=jnp.float32):
    """Prefill compute (recurrent blocks + local-attention K/V + obs-window
    query tails); cache construction runs in the shared
    ``chunked.finalize_pipeline`` (see ``prefill``)."""
    B, S = tokens.shape
    W = policy.obs_window
    w_eff = min(W, S)
    x = common.embed_tokens(tokens, params, cfg)
    rec_states, kv_layers = [], []
    for i, kind in enumerate(cfg.layer_kinds):
        lp = params["layers"][i]
        if kind == RGLRU:
            x, st = _rglru_block_seq(x, lp, cfg, None)
            rec_states.append(st)
        else:
            h = common.apply_norm(x, lp["norm"], cfg)
            q, k, v = attention.project_qkv(h, lp["attn"], cfg)
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            q, k = attention._rope(q, k, positions, cfg)
            qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
            from repro.kernels import ops
            attn_raw = ops.prefill_attention(
                qh, kh, vh, causal=True, window=cfg.sliding_window,
                scale=cfg.d_head ** -0.5)
            out = jnp.swapaxes(attn_raw, 1, 2).reshape(B, S, -1) \
                @ lp["attn"]["wo"]
            q_tail = jnp.pad(qh[:, :, S - w_eff:].astype(jnp.float32),
                             ((0, 0), (0, 0), (W - w_eff, 0), (0, 0)))
            x = x + out
            h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
            x = x + common.apply_mlp(h2, lp["mlp"], cfg)
            kv_layers.append((kh.astype(cache_dtype), vh.astype(cache_dtype),
                              q_tail))
    k_all = jnp.stack([t[0] for t in kv_layers])
    v_all = jnp.stack([t[1] for t in kv_layers])
    q_tails = jnp.stack([t[2] for t in kv_layers])
    rec = jax.tree.map(lambda *xs: jnp.stack(xs), *rec_states)
    return x[:, -1], k_all, v_all, q_tails, rec


@functools.partial(jax.jit, static_argnames=("cfg",))
def _head(params, x_last, cfg: ArchConfig):
    return common.unembed(x_last, params, cfg)


def _finalize_kv(k, v, pos, length, q_tails, cfg: ArchConfig,
                 policy: PolicyConfig, *, capacity: int, w_eff: int,
                 k_extent: int, cur_pos, batch: int):
    from repro.models import chunked
    n_attn = len(_attn_layer_ids(cfg))
    nominal = min(policy.nominal_budget, capacity)
    return chunked.finalize_pipeline(
        k, v, pos, length, q_tails,
        jnp.full((n_attn,), cfg.sliding_window, jnp.int32), cur_pos,
        jnp.full((n_attn, batch), nominal, jnp.int32),
        policy=policy, capacity=capacity, w_eff=w_eff, k_extent=k_extent,
        softcap=None, scale=cfg.d_head ** -0.5, allocate=False,
        evict_cap=False)


def prefill(params, tokens, cfg: ArchConfig, policy: PolicyConfig, *,
            capacity=None, cache_dtype=jnp.float32, **_):
    from repro.models import chunked
    B, S = tokens.shape
    C = capacity or policy.capacity
    n_attn = len(_attn_layer_ids(cfg))
    x_last, k_all, v_all, q_tails, rec = _prefill_compute(
        params, tokens, cfg, policy, cache_dtype=cache_dtype)
    logits = _head(params, x_last, cfg)
    k_extent = chunked.next_pow2(S)
    eb = max(C, k_extent)
    pos = jnp.broadcast_to(
        jnp.where(jnp.arange(eb) < S, jnp.arange(eb), -1).astype(jnp.int32),
        (n_attn, B, eb))
    kv = _finalize_kv(
        chunked.pad_to_extent(k_all, eb, axis=3),
        chunked.pad_to_extent(v_all, eb, axis=3), pos,
        jnp.full((n_attn, B), S, jnp.int32), q_tails, cfg, policy,
        capacity=C, w_eff=min(policy.obs_window, S), k_extent=k_extent,
        cur_pos=jnp.asarray(S - 1, jnp.int32), batch=B)
    return logits, {"rec": rec, "kv": kv}


# --------------------------------------------------------------------------
# Chunked prefill: recurrent blocks carry their (h, conv) state across
# chunks (exact — the recurrence is sequential); only the 1-in-3 local-
# attention layers stream through a working buffer. Note the recurrent
# layers run ``associative_scan`` whose reduction tree depends on the chunk
# split, so chunked hidden states match the whole pass to float tolerance,
# not bit-for-bit (tests/test_chunked_prefill.py treats this family
# accordingly).
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "policy", "chunk_max",
                                             "capacity", "cache_dtype"))
def prefill_chunk_init(params, tokens, cfg: ArchConfig,
                       policy: PolicyConfig, *, chunk_max: int,
                       capacity: int | None = None,
                       cache_dtype=jnp.float32, **_) -> dict:
    from repro.models import chunked
    B = tokens.shape[0]
    C = capacity or policy.capacity
    n_attn = len(_attn_layer_ids(cfg))
    n_rec = cfg.n_layers - n_attn
    w = cfg.lru_width or cfg.d_model
    nominal = min(policy.nominal_budget, C)
    return {
        "buf": chunked.init_buffer(
            n_layers=n_attn, batch=B, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, buf_capacity=C + chunk_max,
            budgets0=jnp.full((n_attn, B), nominal, jnp.int32),
            dtype=cache_dtype),
        "q_tail": chunked.init_q_tail(
            n_layers=n_attn, batch=B, n_heads=cfg.n_heads,
            d_head=cfg.d_head, obs_window=policy.obs_window),
        "extra": {"rec": {
            "h": jnp.zeros((n_rec, B, w), jnp.float32),
            "conv": jnp.zeros((n_rec, B, cfg.conv_width - 1, w),
                              jnp.float32)}},
        "x_last": jnp.zeros((B, cfg.d_model), jnp.float32),
        "done": jnp.zeros((), jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "n",
                                             "capacity", "compress",
                                             "contiguous_offset"),
                   donate_argnames=("carry",))
def prefill_chunk(params, carry, tokens, cfg: ArchConfig,
                  policy: PolicyConfig, *, n: int,
                  capacity: int | None = None, compress: bool = False,
                  contiguous_offset: int | None = None) -> dict:
    import dataclasses as _dc

    from repro.core.policy import LETHE
    from repro.models import chunked
    del n
    C = capacity or policy.capacity
    buf, q_tail, done = carry["buf"], carry["q_tail"], carry["done"]
    rec = carry["extra"]["rec"]
    B, nn = tokens.shape
    if compress and policy.kind == LETHE:
        buf = _dc.replace(buf, budget=chunked.alloc_budgets(
            buf.sparsity, policy, C))
    x = common.embed_tokens(tokens, params, cfg)
    positions = jnp.broadcast_to(jnp.arange(nn, dtype=jnp.int32)
                                 + jnp.asarray(done, jnp.int32), (B, nn))
    win = jnp.asarray(cfg.sliding_window, jnp.int32)
    new_rec, new_kv, new_tails = [], [], []
    ri = ai = 0
    for i, kind in enumerate(cfg.layer_kinds):
        lp = params["layers"][i]
        if kind == RGLRU:
            st = jax.tree.map(lambda a: a[ri], rec)
            x, st2 = _rglru_block_seq(x, lp, cfg, st)
            new_rec.append(st2)
            ri += 1
        else:
            lay = buf.layer(ai)
            h = common.apply_norm(x, lp["norm"], cfg)
            q, k, v = attention.project_qkv(h, lp["attn"], cfg)
            q, k = attention._rope(q, k, positions, cfg)
            qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
            attn_raw, lay = chunked.attend_chunk_layer(
                lay, qh, kh, vh, done, policy=policy, window=win,
                softcap=None, scale=cfg.d_head ** -0.5, capacity=C,
                compress=compress, contiguous_offset=contiguous_offset)
            out = jnp.swapaxes(attn_raw, 1, 2).reshape(B, nn, -1) \
                @ lp["attn"]["wo"]
            x = x + out
            h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
            x = x + common.apply_mlp(h2, lp["mlp"], cfg)
            new_kv.append(lay)
            new_tails.append(chunked.roll_q_tail(q_tail[ai], qh))
            ai += 1
    return {
        "buf": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv),
        "q_tail": jnp.stack(new_tails),
        "extra": {"rec": jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec)},
        "x_last": x[:, -1].astype(jnp.float32),
        "done": jnp.asarray(done, jnp.int32) + nn,
    }


def prefill_finalize(params, carry, cfg: ArchConfig, policy: PolicyConfig,
                     *, w_eff: int, k_extent: int,
                     capacity: int | None = None
                     ) -> tuple[jax.Array, dict]:
    from repro.models import chunked
    C = capacity or policy.capacity
    B = carry["x_last"].shape[0]
    logits = _head(params, carry["x_last"].astype(jnp.float32), cfg)
    # rglru rejects kv_format="int8" at config time (recurrent state is out
    # of scope for KV quantization) — scales here are always None.
    k_e, v_e, pos_e, length, _, _ = chunked.finalize_inputs(
        carry["buf"], capacity=C, k_extent=k_extent)
    kv = _finalize_kv(
        k_e, v_e, pos_e, length, carry["q_tail"], cfg, policy,
        capacity=C, w_eff=w_eff, k_extent=k_extent,
        cur_pos=jnp.asarray(carry["done"], jnp.int32) - 1, batch=B)
    return logits, {"rec": carry["extra"]["rec"], "kv": kv}


@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("state",))
def decode_step(params, state, token, cur_pos, cfg: ArchConfig,
                policy: PolicyConfig, **_):
    # ``state`` (recurrent h/conv + the attention layers' KV cache) is
    # donated so the per-step buffers update in place.
    x = common.embed_tokens(token, params, cfg)
    kv, rec = state["kv"], state["rec"]
    new_kv_layers, new_rec_layers = [], []
    ai = ri = 0
    for i, kind in enumerate(cfg.layer_kinds):
        lp = params["layers"][i]
        if kind == RGLRU:
            st = jax.tree.map(lambda a: a[ri], rec)
            x, st2 = _rglru_block_step(x, lp, cfg, st)
            new_rec_layers.append(st2)
            ri += 1
        else:
            lay = kv.layer(ai)
            h = common.apply_norm(x, lp["norm"], cfg)
            attn_out, lay = attention.decode_attend(
                h, lp["attn"], lay, cur_pos, cfg, policy,
                window=jnp.asarray(cfg.sliding_window, jnp.int32))
            x = x + attn_out
            h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
            x = x + common.apply_mlp(h2, lp["mlp"], cfg)
            new_kv_layers.append(lay)
            ai += 1
    new_state = {
        "rec": jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec_layers),
        "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv_layers),
    }
    logits = common.unembed(x, params, cfg)
    return logits, new_state


def init_decode_state(cfg: ArchConfig, policy: PolicyConfig, batch: int,
                      dtype=jnp.float32) -> dict:
    n_attn = len(_attn_layer_ids(cfg))
    n_rec = cfg.n_layers - n_attn
    w = cfg.lru_width or cfg.d_model
    kv = cache_lib.init_cache(
        n_layers=n_attn, batch=batch, n_kv_heads=cfg.n_kv_heads,
        capacity=policy.capacity, d_head=cfg.d_head, policy=policy,
        dtype=dtype)
    rec = {"h": jnp.zeros((n_rec, batch, w), jnp.float32),
           "conv": jnp.zeros((n_rec, batch, cfg.conv_width - 1, w), dtype)}
    return {"rec": rec, "kv": kv}
