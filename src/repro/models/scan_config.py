"""Layer-scan unroll control.

XLA's ``cost_analysis`` counts a ``while``-loop body ONCE, not × trip-count,
so roofline numbers taken from a scanned-layer model undercount FLOPs/bytes
by ~n_layers. The dry-run proof-of-lowering keeps the compact scan (fast
compiles); roofline measurement runs set REPRO_UNROLL_LAYERS=1 so every
layer scan is fully unrolled (scan with unroll=length → single iteration →
costs counted exactly once each).

Time-axis scans (RWKV6 / RG-LRU recurrences over 32k+ steps) are never
unrolled; their roofline compute term is derived analytically instead
(EXPERIMENTS.md §Roofline notes).
"""
from __future__ import annotations

import os

import jax


def unroll_layers() -> bool:
    return os.environ.get("REPRO_UNROLL_LAYERS", "0") == "1"


def layer_scan(body, init, xs, length: int | None = None):
    """lax.scan over stacked layers, honouring the unroll flag."""
    if unroll_layers():
        return jax.lax.scan(body, init, xs, length=length,
                            unroll=True)
    return jax.lax.scan(body, init, xs, length=length)


def remat_layers() -> bool:
    """REPRO_REMAT=1 -> per-layer activation checkpointing in train paths.
    Trades ~+33% layer FLOPs for O(L)->O(1) activation residency — the
    §Perf fix for activation-memory-bound training (arctic train_4k)."""
    return os.environ.get("REPRO_REMAT", "0") == "1"


def maybe_remat(body):
    if remat_layers():
        return jax.checkpoint(body)
    return body
