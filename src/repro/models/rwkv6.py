"""RWKV6 "Finch" — attention-free linear-recurrence model with
data-dependent decay [arXiv:2404.05892].

No KV cache exists: per-layer state is a fixed [B, H, N, N] matrix plus two
token-shift vectors, so memory is O(1) in sequence length and Lethe is
structurally inapplicable (DESIGN.md §Arch-applicability). Recurrence:

    y_t[j] = Σ_i r_t[i] · (S_{t-1}[i,j] + u[i]·k_t[i]·v_t[j])
    S_t[i,j] = w_t[i] · S_{t-1}[i,j] + k_t[i]·v_t[j]

with the Finch signature feature: per-channel decay w_t = exp(-exp(·))
computed from the *input* via a low-rank MLP (data-dependent decay), and
DDLerp token-shift mixing for r/k/v/w/g.

Training/prefill run the recurrence with ``lax.scan`` over time; decode is a
single step of the same function.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.scan_config import layer_scan

_DDLERP_RANK = 32
_DECAY_RANK = 64
_GATES = ("r", "k", "v", "w", "g")


def _init_layer(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = d // n
    ks = jax.random.split(key, 16)
    p = {
        "ln_tm": common.init_norm(ks[0], d, cfg, dtype),
        "ln_cm": common.init_norm(ks[1], d, cfg, dtype),
        # token-shift baselines
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu": jnp.full((len(_GATES), d), 0.5, dtype),
        # DDLerp low-rank correction (shared A, per-gate B)
        "ddl_a": common.dense_init(ks[2], (d, _DDLERP_RANK * len(_GATES)),
                                   dtype),
        "ddl_b": common.dense_init(
            ks[3], (len(_GATES), _DDLERP_RANK, d), dtype),
        # data-dependent decay
        "w0": jnp.full((d,), -0.6, dtype),
        "wd1": common.dense_init(ks[4], (d, _DECAY_RANK), dtype),
        "wd2": common.dense_init(ks[5], (_DECAY_RANK, d), dtype),
        "u": common.dense_init(ks[6], (h, n), dtype, scale=0.5),
        "wr": common.dense_init(ks[7], (d, d), dtype),
        "wk": common.dense_init(ks[8], (d, d), dtype),
        "wv": common.dense_init(ks[9], (d, d), dtype),
        "wg": common.dense_init(ks[10], (d, d), dtype),
        "wo": common.dense_init(ks[11], (d, d), dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
        # channel mix
        "mu_ck": jnp.full((d,), 0.5, dtype),
        "mu_cr": jnp.full((d,), 0.5, dtype),
        "cm_k": common.dense_init(ks[12], (d, cfg.d_ff), dtype),
        "cm_v": common.dense_init(ks[13], (cfg.d_ff, d), dtype),
        "cm_r": common.dense_init(ks[14], (d, d), dtype),
    }
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    return {
        "embed": common.embed_init(ks[1], (cfg.vocab_size, cfg.d_model),
                                   dtype),
        "layers": layers,
        "final_norm": common.init_norm(ks[2], cfg.d_model, cfg, dtype),
        "unembed": common.dense_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                     dtype),
    }


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = d // n
    L = cfg.n_layers
    return {
        "wkv": jnp.zeros((L, batch, h, n, n), jnp.float32),
        "x_tm": jnp.zeros((L, batch, d), dtype),
        "x_cm": jnp.zeros((L, batch, d), dtype),
    }


def _ddlerp(x, x_prev, lp):
    """Data-dependent token-shift interpolation -> per-gate mixed inputs."""
    xx = x_prev - x
    xxx = x + xx * lp["mu_x"]
    lora = jnp.tanh(xxx @ lp["ddl_a"])
    lora = lora.reshape(*lora.shape[:-1], len(_GATES), _DDLERP_RANK)
    delta = jnp.einsum("...gr,grd->...gd", lora, lp["ddl_b"])
    mixed = x[..., None, :] + xx[..., None, :] * (lp["mu"] + delta)
    return tuple(mixed[..., i, :] for i in range(len(_GATES)))


def _time_mix_step(lp, cfg: ArchConfig, x, x_prev, S):
    """One token of the WKV6 recurrence. x [B, D]; S [B, H, N, N]."""
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = d // n
    xr, xk, xv, xw, xg = _ddlerp(x, x_prev, lp)
    r = (xr @ lp["wr"]).reshape(-1, h, n)
    k = (xk @ lp["wk"]).reshape(-1, h, n)
    v = (xv @ lp["wv"]).reshape(-1, h, n)
    g = jax.nn.silu(xg @ lp["wg"])
    # data-dependent decay (Finch): w in (0, 1) per channel
    decay_in = xw @ lp["wd1"]
    w = lp["w0"] + jnp.tanh(decay_in) @ lp["wd2"]
    w = jnp.exp(-jnp.exp(w.astype(jnp.float32))).reshape(-1, h, n)

    Sf = S.astype(jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]          # [B,H,N,N]
    y = jnp.einsum("bhi,bhij->bhj", rf,
                   Sf + lp["u"].astype(jnp.float32)[None, :, :, None] * kv)
    S_new = w[..., :, None] * Sf + kv
    y = y.reshape(-1, d)
    # per-head group norm
    yg = y.reshape(-1, h, n)
    mu = jnp.mean(yg, -1, keepdims=True)
    var = jnp.var(yg, -1, keepdims=True)
    yg = (yg - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yg.reshape(-1, d) * lp["gn_scale"] + lp["gn_bias"]
    out = (y.astype(x.dtype) * g) @ lp["wo"]
    return out, S_new


def _channel_mix_step(lp, cfg: ArchConfig, x, x_prev):
    xx = x_prev - x
    xk = x + xx * lp["mu_ck"]
    xr = x + xx * lp["mu_cr"]
    kk = jax.nn.relu(xk @ lp["cm_k"])
    kk = kk * kk
    return jax.nn.sigmoid(xr @ lp["cm_r"]) * (kk @ lp["cm_v"])


def _layer_seq(lp, cfg: ArchConfig, x, state_l):
    """Full-sequence layer via scan over time. x [B, S, D]."""
    B, S, D = x.shape

    def step(carry, xt):
        S_wkv, x_tm, x_cm = carry
        h = common.apply_norm(xt, lp["ln_tm"], cfg)
        tm_out, S_new = _time_mix_step(lp, cfg, h, x_tm, S_wkv)
        y = xt + tm_out
        h2 = common.apply_norm(y, lp["ln_cm"], cfg)
        cm_out = _channel_mix_step(lp, cfg, h2, x_cm)
        y = y + cm_out
        return (S_new, h, h2), y

    (S_wkv, x_tm, x_cm), ys = jax.lax.scan(
        step, (state_l["wkv"], state_l["x_tm"], state_l["x_cm"]),
        jnp.swapaxes(x, 0, 1))
    new_state = {"wkv": S_wkv, "x_tm": x_tm, "x_cm": x_cm}
    return jnp.swapaxes(ys, 0, 1), new_state


@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params: dict, tokens: jax.Array, cfg: ArchConfig, **_
                  ) -> tuple[jax.Array, jax.Array]:
    B, S = tokens.shape
    x = common.embed_tokens(tokens, params, cfg)
    state = init_state(cfg, B, x.dtype)

    def body(carry, xs):
        lp, st = xs
        y, _ = _layer_seq(lp, cfg, carry, st)
        return y, None

    x, _ = layer_scan(body, x, (params["layers"], state))
    logits = common.unembed(x, params, cfg)
    return logits, jnp.float32(0.0)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "capacity",
                                             "cache_dtype"))
def _prefill_compute(params: dict, tokens: jax.Array, cfg: ArchConfig,
                     policy=None, *, capacity=None, cache_dtype=None, **_):
    B, S = tokens.shape
    x = common.embed_tokens(tokens, params, cfg)
    state = init_state(cfg, B, x.dtype)

    def body(carry, xs):
        lp, st = xs
        y, new_st = _layer_seq(lp, cfg, carry, st)
        return y, new_st

    x, new_state = layer_scan(body, x, (params["layers"], state))
    return x[:, -1], new_state


@functools.partial(jax.jit, static_argnames=("cfg",))
def _head(params: dict, x_last: jax.Array, cfg: ArchConfig):
    return common.unembed(x_last, params, cfg)


def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig, policy=None,
            *, capacity=None, cache_dtype=None, **_):
    """Returns (last-token logits, recurrent state). Policy is ignored —
    the state is O(1); there is nothing to prune. The logits head is the
    same compiled program chunked prefill finalizes through."""
    x_last, state = _prefill_compute(params, tokens, cfg, policy,
                                     capacity=capacity,
                                     cache_dtype=cache_dtype)
    return _head(params, x_last, cfg), state


# --------------------------------------------------------------------------
# Chunked prefill: the recurrence is a sequential time-scan, so chunking is
# exact by construction — run the same scan chunk by chunk with the carried
# state. No KV cache exists, hence no working buffer, no compression, and
# no capacity limit on prompt length (memory is O(1) in S).
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "policy", "chunk_max",
                                             "capacity", "cache_dtype"))
def prefill_chunk_init(params: dict, tokens: jax.Array, cfg: ArchConfig,
                       policy=None, *, chunk_max: int = 0, capacity=None,
                       cache_dtype=None, **_) -> dict:
    B = tokens.shape[0]
    return {
        "state": init_state(cfg, B, jnp.float32),
        "extra": {},
        "x_last": jnp.zeros((B, cfg.d_model), jnp.float32),
        "done": jnp.zeros((), jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "n",
                                             "capacity", "compress",
                                             "contiguous_offset"),
                   donate_argnames=("carry",))
def prefill_chunk(params: dict, carry: dict, tokens: jax.Array,
                  cfg: ArchConfig, policy=None, *, n: int = 0,
                  capacity=None, compress: bool = False,
                  contiguous_offset=None) -> dict:
    del n, compress, contiguous_offset
    B, nn = tokens.shape
    x = common.embed_tokens(tokens, params, cfg)

    def body(xc, xs):
        lp, st = xs
        y, new_st = _layer_seq(lp, cfg, xc, st)
        return y, new_st

    x, new_state = layer_scan(body, x, (params["layers"], carry["state"]))
    return {"state": new_state, "extra": {},
            "x_last": x[:, -1].astype(jnp.float32),
            "done": jnp.asarray(carry["done"], jnp.int32) + nn}


def prefill_finalize(params: dict, carry: dict, cfg: ArchConfig,
                     policy=None, *, w_eff: int = 0, k_extent: int = 0,
                     capacity=None) -> tuple[jax.Array, dict]:
    del w_eff, k_extent
    return _head(params, carry["x_last"], cfg), carry["state"]


@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("state",))
def decode_step(params: dict, state: dict, token: jax.Array, cur_pos,
                cfg: ArchConfig, policy=None, **_):
    # ``state`` (per-layer wkv matrix + token-shift vectors) is donated so
    # the recurrent buffers update in place each step.
    x = common.embed_tokens(token, params, cfg)   # [B, D]

    def body(carry, xs):
        lp, st = xs
        h = common.apply_norm(carry, lp["ln_tm"], cfg)
        tm_out, S_new = _time_mix_step(lp, cfg, h, st["x_tm"], st["wkv"])
        y = carry + tm_out
        h2 = common.apply_norm(y, lp["ln_cm"], cfg)
        cm_out = _channel_mix_step(lp, cfg, h2, st["x_cm"])
        y = y + cm_out
        return y, {"wkv": S_new, "x_tm": h, "x_cm": h2}

    x, new_state = layer_scan(body, x, (params["layers"], state))
    logits = common.unembed(x, params, cfg)
    return logits, new_state


def init_decode_state(cfg: ArchConfig, policy, batch: int,
                      dtype=jnp.float32) -> dict:
    return init_state(cfg, batch, dtype)
