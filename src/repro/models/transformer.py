"""Generic decoder-only transformer (dense / GQA / MoE / local-global),
built for ``lax.scan`` over stacked layer parameters so that 64-layer dry-run
lowerings stay compact.

Covers: command-r-35b (parallel block), qwen2.5-32b (qkv bias), gemma2-27b
(alternating local/global + softcaps + sandwich norms), granite-20b (MQA),
mixtral-8x7b (MoE + SWA), arctic-480b (MoE + dense residual), qwen2-vl-2b
(M-RoPE + stub vision embeds, via models/vlm.py).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, LOCAL_ATTN, ArchConfig
from repro.core import cache as cache_lib
from repro.core import sparsity as sparsity_lib
from repro.core.policy import LETHE, PYRAMIDKV, PolicyConfig
from repro.models import attention, common, moe
from repro.models.scan_config import layer_scan, maybe_remat

GLOBAL_WINDOW = 1 << 30  # sentinel: effectively unwindowed


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "attn_norm": common.init_norm(ks[0], cfg.d_model, cfg, dtype),
        "attn": attention.init_attention(ks[1], cfg, dtype),
    }
    if not cfg.parallel_block:
        p["ffn_norm"] = common.init_norm(ks[2], cfg.d_model, cfg, dtype)
    if cfg.sandwich_norm:
        p["post_attn_norm"] = common.init_norm(ks[3], cfg.d_model, cfg, dtype)
        p["post_ffn_norm"] = common.init_norm(ks[4], cfg.d_model, cfg, dtype)
    if cfg.n_experts:
        p["moe"] = moe.init_moe(ks[5], cfg, dtype)
    else:
        p["mlp"] = common.init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg, dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": common.embed_init(ks[1], (cfg.vocab_size, cfg.d_model),
                                   dtype),
        "layers": layers,
        "final_norm": common.init_norm(ks[2], cfg.d_model, cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = common.dense_init(
            ks[3], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def layer_windows(cfg: ArchConfig) -> jax.Array:
    """Per-layer attention window ([L] int32; GLOBAL_WINDOW = full)."""
    w = []
    for kind in cfg.layer_kinds:
        if kind == LOCAL_ATTN or (kind == ATTN and cfg.sliding_window
                                  and not cfg.local_global_period):
            w.append(cfg.sliding_window)
        elif kind == ATTN and cfg.local_global_period:
            w.append(GLOBAL_WINDOW)
        elif kind == ATTN:
            w.append(GLOBAL_WINDOW)
        else:
            w.append(GLOBAL_WINDOW)
    # gemma2: local layers get cfg.sliding_window
    if cfg.local_global_period and cfg.sliding_window:
        w = [cfg.sliding_window if k == LOCAL_ATTN else GLOBAL_WINDOW
             for k in cfg.layer_kinds]
    return jnp.asarray(w, jnp.int32)


# --------------------------------------------------------------------------
# Layer bodies
# --------------------------------------------------------------------------

def _ffn(h: jax.Array, lp: dict, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    if cfg.n_experts:
        return moe.apply_moe(h, lp["moe"], cfg)
    return common.apply_mlp(h, lp["mlp"], cfg), jnp.float32(0.0)


def _layer_full(x: jax.Array, lp: dict, cfg: ArchConfig, window,
                positions, positions3) -> tuple[jax.Array, jax.Array]:
    """Full-sequence layer (train/prefill compute). Returns (x, moe_aux)."""
    h = common.apply_norm(x, lp["attn_norm"], cfg)
    attn_out = attention.attend_full(
        h, lp["attn"], cfg, window=window, positions=positions,
        positions3=positions3)
    if cfg.parallel_block:
        ffn_out, aux = _ffn(h, lp, cfg)
        return x + attn_out + ffn_out, aux
    if cfg.sandwich_norm:
        attn_out = common.apply_norm(attn_out, lp["post_attn_norm"], cfg)
    x = x + attn_out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    ffn_out, aux = _ffn(h2, lp, cfg)
    if cfg.sandwich_norm:
        ffn_out = common.apply_norm(ffn_out, lp["post_ffn_norm"], cfg)
    return x + ffn_out, aux


# --------------------------------------------------------------------------
# Train forward
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params: dict, tokens: jax.Array, cfg: ArchConfig, *,
                  embeds: jax.Array | None = None,
                  positions3: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (logits [B, S, V], moe_aux_loss scalar)."""
    x = common.embed_tokens(tokens, params, cfg)
    if embeds is not None:  # VLM: prepend/replace with frontend embeds
        x = embeds.astype(x.dtype)
    windows = layer_windows(cfg)

    @maybe_remat
    def body(carry, xs):
        lp, w = xs
        y, aux = _layer_full(carry, lp, cfg, w, None, positions3)
        return y, aux

    x, auxs = layer_scan(body, x, (params["layers"], windows))
    logits = common.unembed(x, params, cfg)
    return logits, jnp.sum(auxs)


# --------------------------------------------------------------------------
# Prefill: full-seq compute + cache construction + Lethe spatial allocation
# --------------------------------------------------------------------------

def _init_budgets(cfg: ArchConfig, policy: PolicyConfig) -> jax.Array:
    L = cfg.n_layers
    nominal = min(policy.nominal_budget, policy.capacity)
    if policy.kind == PYRAMIDKV:
        sched = np.linspace(policy.pyramid_bottom_ratio,
                            policy.pyramid_top_ratio, L)
        sched = sched / sched.mean()
        b = np.clip((sched * nominal).astype(np.int32),
                    policy.sink_len + 2, int(policy.capacity * 15 / 16))
        return jnp.asarray(b, jnp.int32)
    return jnp.full((L,), nominal, jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "capacity",
                                             "cache_dtype"))
def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
            policy: PolicyConfig, *, capacity: int | None = None,
            embeds: jax.Array | None = None,
            positions3: jax.Array | None = None,
            cache_dtype=jnp.float32
            ) -> tuple[jax.Array, cache_lib.KVCache]:
    """tokens [B, S] -> (last-token logits [B, V], initialised KVCache).

    Runs full-sequence attention per layer, collects per-layer K/V +
    observation-window RASR scores + Hoyer sparsity, fills the slotted cache,
    performs Lethe's spatial budget allocation and one forced prune round.
    """
    B, S = tokens.shape[0], tokens.shape[1]
    C = capacity or policy.capacity
    x = common.embed_tokens(tokens, params, cfg)
    if embeds is not None:
        x = embeds.astype(x.dtype)
    windows = layer_windows(cfg)

    def body(carry, xs):
        lp, w = xs
        h = common.apply_norm(carry, lp["attn_norm"], cfg)
        q, k, v = attention.project_qkv(h, lp["attn"], cfg)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q, k = attention._rope(q, k, positions, cfg, positions3)
        qh = jnp.swapaxes(q, 1, 2)
        kh = jnp.swapaxes(k, 1, 2)
        vh = jnp.swapaxes(v, 1, 2)
        from repro.kernels import ops
        from repro.models import shard_hints
        qh, kh, vh = shard_hints.prefill_attention_hints(qh, kh, vh)
        attn_raw = ops.prefill_attention(
            qh, kh, vh, causal=True, window=w,
            softcap=cfg.attn_logit_softcap, scale=cfg.d_head ** -0.5)
        attn_raw = shard_hints.prefill_out_hint(attn_raw)
        attn_out = jnp.swapaxes(attn_raw, 1, 2).reshape(B, S, -1) \
            @ lp["attn"]["wo"]
        scores, spars = attention.prefill_stats(qh, kh, cfg, policy, window=w)

        if cfg.parallel_block:
            ffn_out, _ = _ffn(h, lp, cfg)
            y = carry + attn_out + ffn_out
        else:
            if cfg.sandwich_norm:
                attn_out = common.apply_norm(attn_out, lp["post_attn_norm"],
                                             cfg)
            y = carry + attn_out
            h2 = common.apply_norm(y, lp["ffn_norm"], cfg)
            ffn_out, _ = _ffn(h2, lp, cfg)
            if cfg.sandwich_norm:
                ffn_out = common.apply_norm(ffn_out, lp["post_ffn_norm"], cfg)
            y = y + ffn_out
        return y, (kh.astype(cache_dtype), vh.astype(cache_dtype), scores,
                   spars)

    x, (k_all, v_all, scores_all, spars_all) = layer_scan(
        body, x, (params["layers"], windows))

    logits = common.unembed(x[:, -1], params, cfg)

    # ---- cache construction -------------------------------------------------
    fill = jax.vmap(lambda k, v, s: cache_lib.fill_from_prefill(
        k=k, v=v, scores=s, capacity=C))
    k_c, v_c, pos_c, score_c, len_c = fill(k_all, v_all, scores_all)

    if policy.kind == LETHE:
        budgets = sparsity_lib.allocate_budgets_batched(
            spars_all, capacity=C,
            nominal=min(policy.nominal_budget, C),
            min_budget=max(policy.sink_len + policy.recent_len + 2,
                           int(policy.min_budget_ratio
                               * min(policy.nominal_budget, C))),
            sink_len=policy.sink_len, recent_len=policy.recent_len)
    else:
        budgets = jnp.broadcast_to(_init_budgets(cfg, policy)[:, None],
                                   (cfg.n_layers, B))
    cache = cache_lib.KVCache(
        k=k_c, v=v_c, pos=pos_c, score=score_c, length=len_c,
        budget=budgets, evict_at=jnp.minimum(budgets, C).astype(jnp.int32),
        sparsity=spars_all)

    if policy.prunes:
        from repro.core import pruning
        cur = jnp.asarray(S - 1, jnp.int32)
        prune_l = jax.vmap(
            lambda lay, w: pruning.prune_layer(lay, cur, policy=policy,
                                               window=w, force=True))
        cache = prune_l(cache, windows)
    return logits, cache


# --------------------------------------------------------------------------
# Decode step
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("cache",))
def decode_step(params: dict, cache: cache_lib.KVCache, token: jax.Array,
                cur_pos: jax.Array, cfg: ArchConfig, policy: PolicyConfig, *,
                positions3: jax.Array | None = None
                ) -> tuple[jax.Array, cache_lib.KVCache]:
    """token [B] at position ``cur_pos`` -> (logits [B, V], cache').

    The cache pytree is *donated*: XLA aliases the [L, B, Hkv, C, Dh] K/V
    buffers between input and output and updates them in place, so a decode
    step allocates no second cache copy. Callers must treat the passed-in
    cache as consumed (every driver rebinds ``state`` each step)."""
    x = common.embed_tokens(token, params, cfg)     # [B, D]
    windows = layer_windows(cfg)

    def body(carry, xs):
        lp, lay, w = xs
        h = common.apply_norm(carry, lp["attn_norm"], cfg)
        attn_out, lay = attention.decode_attend(
            h, lp["attn"], lay, cur_pos, cfg, policy, window=w,
            positions3=positions3)
        if cfg.parallel_block:
            ffn_out, _ = _ffn(h, lp, cfg)
            y = carry + attn_out + ffn_out
        else:
            if cfg.sandwich_norm:
                attn_out = common.apply_norm(attn_out, lp["post_attn_norm"],
                                             cfg)
            y = carry + attn_out
            h2 = common.apply_norm(y, lp["ffn_norm"], cfg)
            ffn_out, _ = _ffn(h2, lp, cfg)
            if cfg.sandwich_norm:
                ffn_out = common.apply_norm(ffn_out, lp["post_ffn_norm"], cfg)
            y = y + ffn_out
        return y, lay

    x, new_cache = layer_scan(body, x, (params["layers"], cache, windows))

    # Temporal re-allocation of spatial budgets from the per-row sparsity
    # EMA (each serving slot gets its own per-layer allocation).
    if policy.kind == LETHE:
        C = cache.capacity
        budgets = sparsity_lib.allocate_budgets_batched(
            new_cache.sparsity, capacity=C,
            nominal=min(policy.nominal_budget, C),
            min_budget=max(policy.sink_len + policy.recent_len + 2,
                           int(policy.min_budget_ratio
                               * min(policy.nominal_budget, C))),
            sink_len=policy.sink_len, recent_len=policy.recent_len)
        new_cache = cache_lib.KVCache(
            k=new_cache.k, v=new_cache.v, pos=new_cache.pos,
            score=new_cache.score, length=new_cache.length,
            budget=budgets,
            evict_at=jnp.maximum(new_cache.evict_at, budgets),
            sparsity=new_cache.sparsity)

    logits = common.unembed(x, params, cfg)
    return logits, new_cache


def init_decode_state(cfg: ArchConfig, policy: PolicyConfig, batch: int,
                      dtype=jnp.float32) -> cache_lib.KVCache:
    cache = cache_lib.init_cache(
        n_layers=cfg.n_layers, batch=batch, n_kv_heads=cfg.n_kv_heads,
        capacity=policy.capacity, d_head=cfg.d_head, policy=policy,
        dtype=dtype)
    budgets = jnp.broadcast_to(_init_budgets(cfg, policy)[:, None],
                               (cfg.n_layers, batch))
    return cache_lib.KVCache(
        k=cache.k, v=cache.v, pos=cache.pos, score=cache.score,
        length=cache.length, budget=budgets,
        evict_at=jnp.minimum(budgets, policy.capacity).astype(jnp.int32),
        sparsity=cache.sparsity)
