"""Generic decoder-only transformer (dense / GQA / MoE / local-global),
built for ``lax.scan`` over stacked layer parameters so that 64-layer dry-run
lowerings stay compact.

Covers: command-r-35b (parallel block), qwen2.5-32b (qkv bias), gemma2-27b
(alternating local/global + softcaps + sandwich norms), granite-20b (MQA),
mixtral-8x7b (MoE + SWA), arctic-480b (MoE + dense residual), qwen2-vl-2b
(M-RoPE + stub vision embeds, via models/vlm.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, LOCAL_ATTN, ArchConfig
from repro.core import cache as cache_lib
from repro.core import sparsity as sparsity_lib
from repro.core.policy import LETHE, PYRAMIDKV, PolicyConfig
from repro.models import attention, common, moe
from repro.models.scan_config import layer_scan, maybe_remat

GLOBAL_WINDOW = 1 << 30  # sentinel: effectively unwindowed


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "attn_norm": common.init_norm(ks[0], cfg.d_model, cfg, dtype),
        "attn": attention.init_attention(ks[1], cfg, dtype),
    }
    if not cfg.parallel_block:
        p["ffn_norm"] = common.init_norm(ks[2], cfg.d_model, cfg, dtype)
    if cfg.sandwich_norm:
        p["post_attn_norm"] = common.init_norm(ks[3], cfg.d_model, cfg, dtype)
        p["post_ffn_norm"] = common.init_norm(ks[4], cfg.d_model, cfg, dtype)
    if cfg.n_experts:
        p["moe"] = moe.init_moe(ks[5], cfg, dtype)
    else:
        p["mlp"] = common.init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg, dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": common.embed_init(ks[1], (cfg.vocab_size, cfg.d_model),
                                   dtype),
        "layers": layers,
        "final_norm": common.init_norm(ks[2], cfg.d_model, cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = common.dense_init(
            ks[3], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def layer_windows(cfg: ArchConfig) -> jax.Array:
    """Per-layer attention window ([L] int32; GLOBAL_WINDOW = full)."""
    w = []
    for kind in cfg.layer_kinds:
        if kind == LOCAL_ATTN or (kind == ATTN and cfg.sliding_window
                                  and not cfg.local_global_period):
            w.append(cfg.sliding_window)
        elif kind == ATTN and cfg.local_global_period:
            w.append(GLOBAL_WINDOW)
        elif kind == ATTN:
            w.append(GLOBAL_WINDOW)
        else:
            w.append(GLOBAL_WINDOW)
    # gemma2: local layers get cfg.sliding_window
    if cfg.local_global_period and cfg.sliding_window:
        w = [cfg.sliding_window if k == LOCAL_ATTN else GLOBAL_WINDOW
             for k in cfg.layer_kinds]
    return jnp.asarray(w, jnp.int32)


# --------------------------------------------------------------------------
# Layer bodies
# --------------------------------------------------------------------------

def _ffn(h: jax.Array, lp: dict, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    if cfg.n_experts:
        return moe.apply_moe(h, lp["moe"], cfg)
    return common.apply_mlp(h, lp["mlp"], cfg), jnp.float32(0.0)


def _layer_full(x: jax.Array, lp: dict, cfg: ArchConfig, window,
                positions, positions3) -> tuple[jax.Array, jax.Array]:
    """Full-sequence layer (train/prefill compute). Returns (x, moe_aux)."""
    h = common.apply_norm(x, lp["attn_norm"], cfg)
    attn_out = attention.attend_full(
        h, lp["attn"], cfg, window=window, positions=positions,
        positions3=positions3)
    if cfg.parallel_block:
        ffn_out, aux = _ffn(h, lp, cfg)
        return x + attn_out + ffn_out, aux
    if cfg.sandwich_norm:
        attn_out = common.apply_norm(attn_out, lp["post_attn_norm"], cfg)
    x = x + attn_out
    h2 = common.apply_norm(x, lp["ffn_norm"], cfg)
    ffn_out, aux = _ffn(h2, lp, cfg)
    if cfg.sandwich_norm:
        ffn_out = common.apply_norm(ffn_out, lp["post_ffn_norm"], cfg)
    return x + ffn_out, aux


# --------------------------------------------------------------------------
# Train forward
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_train(params: dict, tokens: jax.Array, cfg: ArchConfig, *,
                  embeds: jax.Array | None = None,
                  positions3: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (logits [B, S, V], moe_aux_loss scalar)."""
    x = common.embed_tokens(tokens, params, cfg)
    if embeds is not None:  # VLM: prepend/replace with frontend embeds
        x = embeds.astype(x.dtype)
    windows = layer_windows(cfg)

    @maybe_remat
    def body(carry, xs):
        lp, w = xs
        y, aux = _layer_full(carry, lp, cfg, w, None, positions3)
        return y, aux

    x, auxs = layer_scan(body, x, (params["layers"], windows))
    logits = common.unembed(x, params, cfg)
    return logits, jnp.sum(auxs)


# --------------------------------------------------------------------------
# Prefill: full-seq compute + cache construction + Lethe spatial allocation
# --------------------------------------------------------------------------

def _init_budgets(cfg: ArchConfig, policy: PolicyConfig) -> jax.Array:
    L = cfg.n_layers
    nominal = min(policy.nominal_budget, policy.capacity)
    if policy.kind == PYRAMIDKV:
        sched = np.linspace(policy.pyramid_bottom_ratio,
                            policy.pyramid_top_ratio, L)
        sched = sched / sched.mean()
        b = np.clip((sched * nominal).astype(np.int32),
                    policy.sink_len + 2, int(policy.capacity * 15 / 16))
        return jnp.asarray(b, jnp.int32)
    return jnp.full((L,), nominal, jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "cache_dtype"))
def _prefill_compute(params: dict, tokens: jax.Array, cfg: ArchConfig,
                     policy: PolicyConfig, *,
                     embeds: jax.Array | None = None,
                     positions3: jax.Array | None = None,
                     cache_dtype=jnp.float32):
    """Full-sequence prefill *compute*: per-layer attention + FFN, emitting
    the raw ingredients of cache construction — per-layer K/V, the
    right-aligned observation-window query tail, and the last token's final
    hidden state. The statistics/fill/budget/prune tail runs in the shared
    ``chunked.finalize_pipeline`` program (see ``prefill``)."""
    B, S = tokens.shape[0], tokens.shape[1]
    x = common.embed_tokens(tokens, params, cfg)
    if embeds is not None:
        x = embeds.astype(x.dtype)
    windows = layer_windows(cfg)
    W = policy.obs_window
    w_eff = min(W, S)

    def body(carry, xs):
        lp, w = xs
        h = common.apply_norm(carry, lp["attn_norm"], cfg)
        q, k, v = attention.project_qkv(h, lp["attn"], cfg)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q, k = attention._rope(q, k, positions, cfg, positions3)
        qh = jnp.swapaxes(q, 1, 2)
        kh = jnp.swapaxes(k, 1, 2)
        vh = jnp.swapaxes(v, 1, 2)
        from repro.kernels import ops
        from repro.models import shard_hints
        qh, kh, vh = shard_hints.prefill_attention_hints(qh, kh, vh)
        attn_raw = ops.prefill_attention(
            qh, kh, vh, causal=True, window=w,
            softcap=cfg.attn_logit_softcap, scale=cfg.d_head ** -0.5)
        attn_raw = shard_hints.prefill_out_hint(attn_raw)
        attn_out = jnp.swapaxes(attn_raw, 1, 2).reshape(B, S, -1) \
            @ lp["attn"]["wo"]
        q_tail = jnp.pad(qh[:, :, S - w_eff:].astype(jnp.float32),
                         ((0, 0), (0, 0), (W - w_eff, 0), (0, 0)))

        if cfg.parallel_block:
            ffn_out, _ = _ffn(h, lp, cfg)
            y = carry + attn_out + ffn_out
        else:
            if cfg.sandwich_norm:
                attn_out = common.apply_norm(attn_out, lp["post_attn_norm"],
                                             cfg)
            y = carry + attn_out
            h2 = common.apply_norm(y, lp["ffn_norm"], cfg)
            ffn_out, _ = _ffn(h2, lp, cfg)
            if cfg.sandwich_norm:
                ffn_out = common.apply_norm(ffn_out, lp["post_ffn_norm"], cfg)
            y = y + ffn_out
        return y, (kh.astype(cache_dtype), vh.astype(cache_dtype), q_tail)

    x, (k_all, v_all, q_tails) = layer_scan(
        body, x, (params["layers"], windows))
    return x[:, -1], k_all, v_all, q_tails


@functools.partial(jax.jit, static_argnames=("cfg",))
def _head(params: dict, x_last: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Last-token logits — one compiled program shared by whole-prompt and
    chunked prefill (both feed it the same final hidden state)."""
    return common.unembed(x_last, params, cfg)


def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
            policy: PolicyConfig, *, capacity: int | None = None,
            embeds: jax.Array | None = None,
            positions3: jax.Array | None = None,
            cache_dtype=jnp.float32
            ) -> tuple[jax.Array, cache_lib.KVCache]:
    """tokens [B, S] -> (last-token logits [B, V], initialised KVCache).

    Orchestrates two compiled programs: the full-sequence compute
    (``_prefill_compute``) and the shared statistics/fill/budget/prune tail
    (``chunked.finalize_pipeline`` — the *same* program chunked prefill
    finalizes through, which is what makes chunked admission bit-identical
    to this whole-prompt path).
    """
    from repro.models import chunked
    B, S = tokens.shape[0], tokens.shape[1]
    C = capacity or policy.capacity
    x_last, k_all, v_all, q_tails = _prefill_compute(
        params, tokens, cfg, policy, embeds=embeds, positions3=positions3,
        cache_dtype=cache_dtype)
    logits = _head(params, x_last, cfg)

    k_extent = chunked.next_pow2(S)
    eb = max(C, k_extent)
    pos = jnp.broadcast_to(
        jnp.where(jnp.arange(eb) < S, jnp.arange(eb), -1).astype(jnp.int32),
        (cfg.n_layers, B, eb))
    cache = chunked.finalize_pipeline(
        chunked.pad_to_extent(k_all, eb, axis=3),
        chunked.pad_to_extent(v_all, eb, axis=3),
        pos, jnp.full((cfg.n_layers, B), S, jnp.int32), q_tails,
        layer_windows(cfg), jnp.asarray(S - 1, jnp.int32),
        _default_budgets(cfg, policy, B), policy=policy, capacity=C,
        w_eff=min(policy.obs_window, S), k_extent=k_extent,
        softcap=cfg.attn_logit_softcap, scale=cfg.d_head ** -0.5,
        allocate=True, evict_cap=True)
    return logits, cache


# --------------------------------------------------------------------------
# Chunked prefill (DESIGN.md §Prefill): admission as a schedulable unit.
# carry = {"buf": KVCache working buffer [L,B,Hkv,Cbuf,Dh], "q_tail":
# rolling obs-window queries [L,B,Hq,W,Dh], "extra": family state,
# "x_last": [B,D] last final-layer hidden, "done": traced token count}.
# --------------------------------------------------------------------------

def _default_budgets(cfg: ArchConfig, policy: PolicyConfig,
                     batch: int) -> jax.Array:
    return jnp.broadcast_to(_init_budgets(cfg, policy)[:, None],
                            (cfg.n_layers, batch))


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "chunk_max",
                                             "capacity", "cache_dtype"))
def prefill_chunk_init(params: dict, tokens: jax.Array, cfg: ArchConfig,
                       policy: PolicyConfig, *, chunk_max: int,
                       capacity: int | None = None,
                       cache_dtype=jnp.float32, **_) -> dict:
    """Empty chunked-prefill carry (working buffer one chunk larger than
    the final cache, so any chunk fits before compression runs)."""
    from repro.models import chunked
    B = tokens.shape[0]
    C = capacity or policy.capacity
    return {
        "buf": chunked.init_buffer(
            n_layers=cfg.n_layers, batch=B, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, buf_capacity=C + chunk_max,
            budgets0=_default_budgets(cfg, policy, B), dtype=cache_dtype,
            kv_format=policy.kv_format),
        "q_tail": chunked.init_q_tail(
            n_layers=cfg.n_layers, batch=B, n_heads=cfg.n_heads,
            d_head=cfg.d_head, obs_window=policy.obs_window),
        "extra": {},
        "x_last": jnp.zeros((B, cfg.d_model), jnp.float32),
        "done": jnp.zeros((), jnp.int32),
    }


def prefill_chunk_resume(params: dict, rows: cache_lib.KVCache,
                         cfg: ArchConfig, policy: PolicyConfig, *,
                         chunk_max: int, s_prefix: int,
                         capacity: int | None = None,
                         cache_dtype=jnp.float32, **_) -> dict:
    """Chunked-prefill carry that CONTINUES from a restored prefix snapshot
    (the prefix-reuse partial-hit path): the working buffer starts as the
    stored rows (K/V + scales + RASR scores + budget state) instead of
    empty, and ``done`` starts at the prefix length so suffix chunks see
    their true absolute positions.

    The rolling query tail starts at zeros — the snapshot does not carry
    post-RoPE queries. Once the suffix is at least ``obs_window`` tokens
    the tail refills completely and finalize statistics are bit-identical
    to a cold run (the FullKV differential test); shorter suffixes observe
    through a partially-zero tail, an approximation on top of the already
    lossy pruned-prefix resume (DESIGN.md §Prefix-reuse).
    """
    from repro.models import chunked
    del params
    C = capacity or policy.capacity
    B = rows.length.shape[1]
    return {
        "buf": chunked.resume_buffer(rows, buf_capacity=C + chunk_max),
        "q_tail": chunked.init_q_tail(
            n_layers=cfg.n_layers, batch=B, n_heads=cfg.n_heads,
            d_head=cfg.d_head, obs_window=policy.obs_window),
        "extra": {},
        "x_last": jnp.zeros((B, cfg.d_model), jnp.float32),
        "done": jnp.asarray(s_prefix, jnp.int32),
    }


def _prefill_chunk_impl(params: dict, carry: dict, tokens: jax.Array | None,
                        cfg: ArchConfig, policy: PolicyConfig, *,
                        capacity: int | None, compress: bool,
                        contiguous_offset: int | None,
                        embeds: jax.Array | None = None,
                        positions3: jax.Array | None = None) -> dict:
    """Process one prompt chunk through every layer (shared by the dense /
    MoE / VLM families). ``tokens`` [B, n] (or None with ``embeds``
    [B, n, D] supplied — the VLM path). Returns the advanced carry."""
    import dataclasses as _dc

    from repro.models import chunked
    C = capacity or policy.capacity
    buf, q_tail, done = carry["buf"], carry["q_tail"], carry["done"]
    if tokens is not None:
        x = common.embed_tokens(tokens, params, cfg)
    if embeds is not None:
        x = embeds.astype(jnp.float32) if tokens is None \
            else embeds.astype(x.dtype)
    B, n, _ = x.shape
    if compress and policy.kind == LETHE:
        buf = _dc.replace(buf, budget=chunked.alloc_budgets(
            buf.sparsity, policy, C))
    windows = layer_windows(cfg)
    positions = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)
                                 + jnp.asarray(done, jnp.int32), (B, n))

    def body(xc, xs):
        lp, lay, w, qt = xs
        h = common.apply_norm(xc, lp["attn_norm"], cfg)
        q, k, v = attention.project_qkv(h, lp["attn"], cfg)
        q, k = attention._rope(q, k, positions, cfg, positions3)
        qh = jnp.swapaxes(q, 1, 2)
        kh = jnp.swapaxes(k, 1, 2)
        vh = jnp.swapaxes(v, 1, 2)
        attn_raw, lay = chunked.attend_chunk_layer(
            lay, qh, kh, vh, done, policy=policy, window=w,
            softcap=cfg.attn_logit_softcap, scale=cfg.d_head ** -0.5,
            capacity=C, compress=compress,
            contiguous_offset=contiguous_offset)
        attn_out = jnp.swapaxes(attn_raw, 1, 2).reshape(B, n, -1) \
            @ lp["attn"]["wo"]
        if cfg.parallel_block:
            ffn_out, _ = _ffn(h, lp, cfg)
            y = xc + attn_out + ffn_out
        else:
            if cfg.sandwich_norm:
                attn_out = common.apply_norm(attn_out, lp["post_attn_norm"],
                                             cfg)
            y = xc + attn_out
            h2 = common.apply_norm(y, lp["ffn_norm"], cfg)
            ffn_out, _ = _ffn(h2, lp, cfg)
            if cfg.sandwich_norm:
                ffn_out = common.apply_norm(ffn_out, lp["post_ffn_norm"],
                                            cfg)
            y = y + ffn_out
        qt = chunked.roll_q_tail(qt, qh)
        return y, (lay, qt)

    x, (new_buf, new_tail) = layer_scan(
        body, x, (params["layers"], buf, windows, q_tail))
    return {"buf": new_buf, "q_tail": new_tail, "extra": carry["extra"],
            "x_last": x[:, -1].astype(jnp.float32),
            "done": jnp.asarray(done, jnp.int32) + n}


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "n",
                                             "capacity", "compress",
                                             "contiguous_offset"),
                   donate_argnames=("carry",))
def prefill_chunk(params: dict, carry: dict, tokens: jax.Array,
                  cfg: ArchConfig, policy: PolicyConfig, *, n: int,
                  capacity: int | None = None, compress: bool = False,
                  contiguous_offset: int | None = None) -> dict:
    del n   # implied by tokens.shape; kept for a uniform family signature
    return _prefill_chunk_impl(
        params, carry, tokens, cfg, policy, capacity=capacity,
        compress=compress, contiguous_offset=contiguous_offset)


def prefill_finalize(params: dict, carry: dict, cfg: ArchConfig,
                     policy: PolicyConfig, *, w_eff: int, k_extent: int,
                     capacity: int | None = None
                     ) -> tuple[jax.Array, cache_lib.KVCache]:
    """Working buffer -> (last-token logits, decode cache) through the SAME
    compiled head + tail-pipeline programs the whole-prompt ``prefill``
    uses — bit-identity between the two admission paths is a property of
    the shared programs, not of matching math in separate ones."""
    from repro.models import chunked
    C = capacity or policy.capacity
    B = carry["x_last"].shape[0]
    logits = _head(params, carry["x_last"].astype(jnp.float32), cfg)
    k_e, v_e, pos_e, length, ks_e, vs_e = chunked.finalize_inputs(
        carry["buf"], capacity=C, k_extent=k_extent)
    cache = chunked.finalize_pipeline(
        k_e, v_e, pos_e, length, carry["q_tail"], layer_windows(cfg),
        jnp.asarray(carry["done"], jnp.int32) - 1,
        _default_budgets(cfg, policy, B), policy=policy, capacity=C,
        w_eff=w_eff, k_extent=k_extent, softcap=cfg.attn_logit_softcap,
        scale=cfg.d_head ** -0.5, allocate=True, evict_cap=True,
        k_scale=ks_e, v_scale=vs_e)
    return logits, cache


# --------------------------------------------------------------------------
# Decode step
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "policy"),
                   donate_argnames=("cache",))
def decode_step(params: dict, cache: cache_lib.KVCache, token: jax.Array,
                cur_pos: jax.Array, cfg: ArchConfig, policy: PolicyConfig, *,
                positions3: jax.Array | None = None
                ) -> tuple[jax.Array, cache_lib.KVCache]:
    """token [B] at position ``cur_pos`` -> (logits [B, V], cache').

    The cache pytree is *donated*: XLA aliases the [L, B, Hkv, C, Dh] K/V
    buffers between input and output and updates them in place, so a decode
    step allocates no second cache copy. Callers must treat the passed-in
    cache as consumed (every driver rebinds ``state`` each step)."""
    x = common.embed_tokens(token, params, cfg)     # [B, D]
    windows = layer_windows(cfg)

    def body(carry, xs):
        lp, lay, w = xs
        h = common.apply_norm(carry, lp["attn_norm"], cfg)
        attn_out, lay = attention.decode_attend(
            h, lp["attn"], lay, cur_pos, cfg, policy, window=w,
            positions3=positions3)
        if cfg.parallel_block:
            ffn_out, _ = _ffn(h, lp, cfg)
            y = carry + attn_out + ffn_out
        else:
            if cfg.sandwich_norm:
                attn_out = common.apply_norm(attn_out, lp["post_attn_norm"],
                                             cfg)
            y = carry + attn_out
            h2 = common.apply_norm(y, lp["ffn_norm"], cfg)
            ffn_out, _ = _ffn(h2, lp, cfg)
            if cfg.sandwich_norm:
                ffn_out = common.apply_norm(ffn_out, lp["post_ffn_norm"], cfg)
            y = y + ffn_out
        return y, lay

    x, new_cache = layer_scan(body, x, (params["layers"], cache, windows))

    # Temporal re-allocation of spatial budgets from the per-row sparsity
    # EMA (each serving slot gets its own per-layer allocation).
    if policy.kind == LETHE:
        C = cache.capacity
        budgets = sparsity_lib.allocate_budgets_batched(
            new_cache.sparsity, capacity=C,
            nominal=min(policy.nominal_budget, C),
            min_budget=max(policy.sink_len + policy.recent_len + 2,
                           int(policy.min_budget_ratio
                               * min(policy.nominal_budget, C))),
            sink_len=policy.sink_len, recent_len=policy.recent_len)
        new_cache = dataclasses.replace(
            new_cache, budget=budgets,
            evict_at=jnp.maximum(new_cache.evict_at, budgets))

    logits = common.unembed(x, params, cfg)
    return logits, new_cache


def init_decode_state(cfg: ArchConfig, policy: PolicyConfig, batch: int,
                      dtype=jnp.float32) -> cache_lib.KVCache:
    cache = cache_lib.init_cache(
        n_layers=cfg.n_layers, batch=batch, n_kv_heads=cfg.n_kv_heads,
        capacity=policy.capacity, d_head=cfg.d_head, policy=policy,
        dtype=dtype)
    budgets = jnp.broadcast_to(_init_budgets(cfg, policy)[:, None],
                               (cfg.n_layers, batch))
    return dataclasses.replace(
        cache, budget=budgets,
        evict_at=jnp.minimum(budgets, policy.capacity).astype(jnp.int32))
