"""Mixture-of-Experts FFN with capacity-based sort-free dispatch.

Top-k routing with a per-expert capacity buffer (GShard-style token dropping):
tokens are scattered into an [E, cap, D] buffer (overflow assignments are
dropped via out-of-bounds scatter semantics), experts run as one batched
einsum, and results are combined back with the routing weights. FLOPs scale
with k·N·D·F (not E·N·D·F) — honest MoE compute for the roofline.

Expert-parallel sharding: the E axis of the expert weights/buffers is sharded
over the ``model`` mesh axis (see launch/shardings.py); XLA GSPMD inserts the
all-to-all-equivalent collectives around the scatter/gather.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common


def _dispatch_mode() -> int:
    """REPRO_MOE_SHARD_DISPATCH:
      0 (default) — no constraint; GSPMD replicates the dispatch buffer
        (baseline: expert FLOPs fail to shard; buffer grads all-reduce).
      1 — buffer constrained (experts->model, capacity->data): shards the
        einsums but the global-index scatter explodes into cross-axis
        collectives (§Perf: refuted on arctic train_4k).
      2 — experts->model only: the token scatter becomes an all-to-all
        across expert shards and einsums shard over E; capacity stays
        unsharded so scatter indices remain local per expert shard.
    """
    return int(os.environ.get("REPRO_MOE_SHARD_DISPATCH", "0"))


def _constrain(x, spec):
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (single-device tests)


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": common.dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": common.dense_init(ks[1], (e, d, f), dtype),
        "w_up": common.dense_init(ks[2], (e, d, f), dtype),
        "w_down": common.dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.dense_residual_d_ff:
        p["residual_mlp"] = common.init_mlp(
            ks[4], d, cfg.dense_residual_d_ff, cfg, dtype)
    return p


def apply_moe(x: jax.Array, p: dict, cfg: ArchConfig, *,
              capacity_factor: float | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """x: [..., D]. Returns (out [..., D], aux_loss scalar).

    aux_loss is the standard load-balancing loss (mean fraction · mean prob
    per expert · E), usable by the trainer.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    n = xt.shape[0]
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    cf = (capacity_factor if capacity_factor is not None
          else cfg.moe_capacity_factor)
    cap = max(1, min(n, int(math.ceil(n * k / e * cf))))

    logits = xt.astype(jnp.float32) @ p["router"]            # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                   # [N, k]
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # load-balancing aux loss
    frac = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))

    flat_e = top_i.reshape(-1)                               # [N*k]
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)                    # [N*k]

    # within-expert slot: rank of this assignment among same-expert ones
    onehot = flat_e[:, None] == jnp.arange(e)[None, :]       # [N*k, E]
    rank = (jnp.cumsum(onehot, axis=0) - 1)                  # occurrences so far
    slot = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]

    # scatter tokens into [E, cap, D]; slot >= cap drops (capacity overflow)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[flat_e, slot].set(xt[flat_t], mode="drop")
    mode = _dispatch_mode()
    if mode == 1:
        buf = _constrain(buf, ("model", "data", None))
    elif mode == 2:
        buf = _constrain(buf, ("model", None, None))

    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h_gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = common.activation(h_gate, cfg.act) * h_up
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])           # [E, cap, D]
    if mode == 1:
        y = _constrain(y, ("model", "data", None))
    elif mode == 2:
        y = _constrain(y, ("model", None, None))

    # combine: gather each assignment's expert output, weight, scatter-add
    kept = slot < cap
    ya = y[flat_e, jnp.minimum(slot, cap - 1)]               # [N*k, D]
    w = jnp.where(kept, flat_w, 0.0).astype(ya.dtype)
    out = jnp.zeros_like(xt).at[flat_t].add(w[:, None] * ya)

    if "residual_mlp" in p:  # arctic: parallel dense residual MLP
        out = out + common.apply_mlp(xt, p["residual_mlp"], cfg)
    return out.reshape(orig_shape), aux
