"""Unified cache-management policy configuration.

All five methods of the paper's evaluation grid — FullKV, H2O, StreamingLLM,
PyramidKV and Lethe — are expressed through one ``PolicyConfig`` so that the
cache/compaction machinery is shared ("all baselines are re-implemented within
a unified framework", §Experimental Setup).

Paper-hyperparameter mapping:
  * ``sparse_ratio`` (paper default 400)  -> ``sparse_ratio`` = τ of Eq. 4 /
    Algorithm 1. Larger τ ⇒ later breakpoints ⇒ more conservative pruning.
  * ``recent_ratio`` (paper default 0.3) -> fraction of the per-layer budget
    reserved for the most recent tokens, always retained.
  * γ of Eq. 5 -> ``gamma`` (RASR score decay).
  * D of Algorithm 1 -> ``n_segments``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

FULLKV = "fullkv"
LETHE = "lethe"
H2O = "h2o"
STREAMING = "streaming"
PYRAMIDKV = "pyramidkv"

KINDS = (FULLKV, LETHE, H2O, STREAMING, PYRAMIDKV)

# KV-cache storage formats. "bf16" = dense: K/V stored at the engine's
# ``cache_dtype`` (bf16 on TPU, f32 in the CPU tests) — the pre-quantization
# layout, kept bit-identical. "int8" = block-scaled: int8 payloads with one
# f32 scale per (token, kv-head), dequantised inside the attention kernels.
KV_FORMATS = ("bf16", "int8")


@dataclass(frozen=True)
class PolicyConfig:
    kind: str = LETHE
    capacity: int = 1024         # static slots per layer (C); the HBM bound
    sink_len: int = 4            # attention-sink tokens always kept
    recent_ratio: float = 0.3    # fraction of budget kept as recent window
    sparse_ratio: float = 400.0  # τ (Algorithm 1); aka sparse_ratio ablation
    n_segments: int = 8          # D segment probes in Algorithm 1
    gamma: float = 0.95          # RASR EMA decay (Eq. 5)
    target_fill: float = 0.5     # nominal budget = target_fill * capacity
    min_budget_ratio: float = 0.25  # spatial-allocator per-layer floor
    obs_window: int = 32         # prefill observation window (exact colsums)
    init_score: float = 1.0      # RASR score of a freshly appended token
    sparsity_ema: float = 0.9    # decode-time layerwise sparsity EMA
    # PyramidKV schedule endpoints as fractions of nominal budget
    pyramid_top_ratio: float = 0.4
    pyramid_bottom_ratio: float = 1.6
    kv_format: str = "bf16"      # KV storage format (see KV_FORMATS)

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        if self.kv_format not in KV_FORMATS:
            raise ValueError(
                f"unknown kv_format {self.kv_format!r}; "
                f"supported: {KV_FORMATS}")

    @property
    def quantized(self) -> bool:
        return self.kv_format == "int8"

    # -- derived -------------------------------------------------------------
    @property
    def nominal_budget(self) -> int:
        if self.kind == FULLKV:
            return self.capacity
        return max(self.sink_len + 8, int(self.capacity * self.target_fill))

    @property
    def recent_len(self) -> int:
        return max(1, int(self.recent_ratio * self.nominal_budget))

    @property
    def prunes(self) -> bool:
        return self.kind != FULLKV

    def with_capacity(self, capacity: int) -> "PolicyConfig":
        return replace(self, capacity=capacity)


def fullkv(capacity: int, **kw) -> PolicyConfig:
    kw = {k: v for k, v in kw.items()       # rest is irrelevant to FullKV
          if k in ("sink_len", "obs_window", "kv_format")}
    return PolicyConfig(kind=FULLKV, capacity=capacity, **kw)


def lethe(capacity: int = 1024, **kw) -> PolicyConfig:
    return PolicyConfig(kind=LETHE, capacity=capacity, **kw)


def h2o(capacity: int = 1024, **kw) -> PolicyConfig:
    # H2O accumulates raw attention mass without decay.
    kw.setdefault("gamma", 1.0)
    return PolicyConfig(kind=H2O, capacity=capacity, **kw)


def streaming(capacity: int = 1024, **kw) -> PolicyConfig:
    return PolicyConfig(kind=STREAMING, capacity=capacity, **kw)


def pyramidkv(capacity: int = 1024, **kw) -> PolicyConfig:
    kw.setdefault("gamma", 1.0)
    return PolicyConfig(kind=PYRAMIDKV, capacity=capacity, **kw)


PRESETS = {
    FULLKV: fullkv,
    LETHE: lethe,
    H2O: h2o,
    STREAMING: streaming,
    PYRAMIDKV: pyramidkv,
}


def make_policy(kind: str, capacity: int, **kw) -> PolicyConfig:
    return PRESETS[kind](capacity, **kw)
