"""Unified cache-management policy configuration.

All five methods of the paper's evaluation grid — FullKV, H2O, StreamingLLM,
PyramidKV and Lethe — plus the decode-time eviction rivals LazyEviction
(arXiv 2506.15969, lagged eviction with an observation window) and G-KV
(arXiv 2512.00504, age-normalised global-attention scoring) are expressed
through one ``PolicyConfig`` so that the cache/compaction machinery is shared
("all baselines are re-implemented within a unified framework",
§Experimental Setup).

Paper-hyperparameter mapping:
  * ``sparse_ratio`` (paper default 400)  -> ``sparse_ratio`` = τ of Eq. 4 /
    Algorithm 1. Larger τ ⇒ later breakpoints ⇒ more conservative pruning.
  * ``recent_ratio`` (paper default 0.3) -> fraction of the per-layer budget
    reserved for the most recent tokens, always retained.
  * γ of Eq. 5 -> ``gamma`` (RASR score decay).
  * D of Algorithm 1 -> ``n_segments``.
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace

FULLKV = "fullkv"
LETHE = "lethe"
H2O = "h2o"
STREAMING = "streaming"
PYRAMIDKV = "pyramidkv"
LAZYEVICTION = "lazyeviction"
GKV = "gkv"

KINDS = (FULLKV, LETHE, H2O, STREAMING, PYRAMIDKV, LAZYEVICTION, GKV)

# KV-cache storage formats. "bf16" = dense: K/V stored at the engine's
# ``cache_dtype`` (bf16 on TPU, f32 in the CPU tests) — the pre-quantization
# layout, kept bit-identical. "int8" = block-scaled: int8 payloads with one
# f32 scale per (token, kv-head), dequantised inside the attention kernels.
KV_FORMATS = ("bf16", "int8")


@dataclass(frozen=True)
class PolicyConfig:
    kind: str = LETHE
    capacity: int = 1024         # static slots per layer (C); the HBM bound
    sink_len: int = 4            # attention-sink tokens always kept
    recent_ratio: float = 0.3    # fraction of budget kept as recent window
    sparse_ratio: float = 400.0  # τ (Algorithm 1); aka sparse_ratio ablation
    n_segments: int = 8          # D segment probes in Algorithm 1
    gamma: float = 0.95          # RASR EMA decay (Eq. 5)
    target_fill: float = 0.5     # nominal budget = target_fill * capacity
    min_budget_ratio: float = 0.25  # spatial-allocator per-layer floor
    obs_window: int = 32         # prefill observation window (exact colsums)
    init_score: float = 1.0      # RASR score of a freshly appended token
    sparsity_ema: float = 0.9    # decode-time layerwise sparsity EMA
    # PyramidKV schedule endpoints as fractions of nominal budget
    pyramid_top_ratio: float = 0.4
    pyramid_bottom_ratio: float = 1.6
    # LazyEviction: extra decode steps a row observes past its budget before
    # the lagged eviction actually fires (arXiv 2506.15969).
    lag_window: int = 64
    kv_format: str = "bf16"      # KV storage format (see KV_FORMATS)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown policy kind {self.kind!r}; valid kinds are "
                f"{', '.join(KINDS)}")
        if self.kv_format not in KV_FORMATS:
            raise ValueError(
                f"unknown kv_format {self.kv_format!r}; "
                f"supported: {KV_FORMATS}")

    @property
    def quantized(self) -> bool:
        return self.kv_format == "int8"

    # -- derived -------------------------------------------------------------
    @property
    def nominal_budget(self) -> int:
        if self.kind == FULLKV:
            return self.capacity
        return max(self.sink_len + 8, int(self.capacity * self.target_fill))

    @property
    def recent_len(self) -> int:
        return max(1, int(self.recent_ratio * self.nominal_budget))

    @property
    def prunes(self) -> bool:
        return self.kind != FULLKV

    def with_capacity(self, capacity: int) -> "PolicyConfig":
        return replace(self, capacity=capacity)


def fullkv(capacity: int, **kw) -> PolicyConfig:
    field_names = {f.name for f in fields(PolicyConfig)}
    unknown = sorted(set(kw) - field_names)
    if unknown:
        raise ValueError(
            f"unknown PolicyConfig field(s) for fullkv(): {unknown}; "
            f"valid fields are {sorted(field_names)}")
    kw = {k: v for k, v in kw.items()       # rest is irrelevant to FullKV
          if k in ("sink_len", "obs_window", "kv_format")}
    return PolicyConfig(kind=FULLKV, capacity=capacity, **kw)


def lethe(capacity: int = 1024, **kw) -> PolicyConfig:
    return PolicyConfig(kind=LETHE, capacity=capacity, **kw)


def h2o(capacity: int = 1024, **kw) -> PolicyConfig:
    # H2O accumulates raw attention mass without decay.
    kw.setdefault("gamma", 1.0)
    return PolicyConfig(kind=H2O, capacity=capacity, **kw)


def streaming(capacity: int = 1024, **kw) -> PolicyConfig:
    return PolicyConfig(kind=STREAMING, capacity=capacity, **kw)


def pyramidkv(capacity: int = 1024, **kw) -> PolicyConfig:
    kw.setdefault("gamma", 1.0)
    return PolicyConfig(kind=PYRAMIDKV, capacity=capacity, **kw)


def lazyeviction(capacity: int = 1024, **kw) -> PolicyConfig:
    # Lagged eviction: when a row first reaches its budget it keeps
    # everything and opens a ``lag_window``-step observation phase so that
    # recurring reasoning tokens can regain score before the (heavy-hitter)
    # eviction actually fires (arXiv 2506.15969).
    return PolicyConfig(kind=LAZYEVICTION, capacity=capacity, **kw)


def gkv(capacity: int = 1024, **kw) -> PolicyConfig:
    # G-KV scores tokens by *global* attention mass: undecayed accumulation
    # (γ=1 through the kernel epilogue's Eq. 5 path), age-normalised at
    # decide time so old tokens are not favoured merely for having been
    # observed longer (arXiv 2512.00504).
    kw.setdefault("gamma", 1.0)
    return PolicyConfig(kind=GKV, capacity=capacity, **kw)


PRESETS = {
    FULLKV: fullkv,
    LETHE: lethe,
    H2O: h2o,
    STREAMING: streaming,
    PYRAMIDKV: pyramidkv,
    LAZYEVICTION: lazyeviction,
    GKV: gkv,
}


def make_policy(kind: str, capacity: int, **kw) -> PolicyConfig:
    try:
        preset = PRESETS[kind]
    except KeyError:
        raise ValueError(
            f"unknown policy kind {kind!r}; valid kinds are "
            f"{', '.join(PRESETS)}") from None
    return preset(capacity, **kw)
