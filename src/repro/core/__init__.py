"""Lethe core: layer- and time-adaptive KV cache pruning.

Public API:
  policy     — unified PolicyConfig for {fullkv, lethe, h2o, streaming,
               pyramidkv, lazyeviction, gkv}
  cache      — fixed-capacity slotted KV cache pytree + append/compaction
  sparsity   — Hoyer sparsity (Eq. 1) + layerwise budget allocator
  pruning    — Algorithm 1 breakpoint + keep rules + prune rounds
  rasr       — Eq. 5 recency-aware score maintenance
"""
from repro.core.policy import (FULLKV, GKV, H2O, LAZYEVICTION, LETHE,
                               PYRAMIDKV, STREAMING, PolicyConfig,
                               make_policy)
from repro.core.cache import KVCache, init_cache
from repro.core.sparsity import (allocate_budgets, hoyer_sparsity,
                                 layer_sparsity_from_probs,
                                 update_sparsity_ema)
from repro.core.pruning import algorithm1_breakpoint, prune_layer
from repro.core.rasr import global_scores, prefill_scores, update_scores

__all__ = [
    "FULLKV", "GKV", "H2O", "LAZYEVICTION", "LETHE", "PYRAMIDKV",
    "STREAMING",
    "PolicyConfig", "make_policy", "KVCache", "init_cache",
    "allocate_budgets", "hoyer_sparsity", "layer_sparsity_from_probs",
    "update_sparsity_ema", "algorithm1_breakpoint", "prune_layer",
    "global_scores", "prefill_scores", "update_scores",
]
