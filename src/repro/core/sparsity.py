"""Hoyer attention sparsity (Eq. 1) and the layerwise budget allocator.

The spatial half of Lethe: measure per-layer attention sparsity at runtime and
allocate per-layer token budgets from estimated redundancy, replacing uniform
(H2O) or pyramidal (PyramidKV) schedules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-9


def hoyer_sparsity(a: jax.Array, axis: int = -1, where: jax.Array | None = None,
                   n_valid: jax.Array | None = None) -> jax.Array:
    """Hoyer sparsity (Eq. 1) of non-negative vectors along ``axis``.

    Sparsity(a) = (sqrt(n) - ||a||_1 / ||a||_2) / (sqrt(n) - 1), in [0, 1].
    1 = one-hot (maximally selective attention), 0 = uniform.

    ``where`` masks invalid entries; ``n_valid`` overrides n (traced count of
    valid entries, needed for partially-filled caches).
    """
    a = a.astype(jnp.float32)
    if where is not None:
        a = jnp.where(where, a, 0.0)
        if n_valid is None:
            n_valid = jnp.sum(where, axis=axis)
    if n_valid is None:
        n = jnp.asarray(a.shape[axis], jnp.float32)
    else:
        n = jnp.maximum(n_valid.astype(jnp.float32), 2.0)
    l1 = jnp.sum(a, axis=axis)
    l2 = jnp.sqrt(jnp.sum(a * a, axis=axis))
    sqrt_n = jnp.sqrt(n)
    s = (sqrt_n - l1 / jnp.maximum(l2, _EPS)) / jnp.maximum(sqrt_n - 1.0, _EPS)
    return jnp.clip(s, 0.0, 1.0)


def layer_sparsity_from_probs(probs: jax.Array,
                              where: jax.Array | None = None,
                              n_valid: jax.Array | None = None) -> jax.Array:
    """Mean Hoyer sparsity of an attention-prob tensor [..., K] -> scalar.

    Reduces over every leading axis (batch, heads, query rows), matching the
    paper's per-(layer, step) heatmap statistic (Fig. 1).
    """
    s = hoyer_sparsity(probs, axis=-1, where=where, n_valid=n_valid)
    return jnp.mean(s)


def row_sparsity_from_probs(probs: jax.Array,
                            where: jax.Array | None = None,
                            n_valid: jax.Array | None = None) -> jax.Array:
    """Per-request Hoyer sparsity of an attention-prob tensor [B, ..., K]
    -> [B]: reduces over heads/query rows but keeps the batch axis, so each
    serving slot carries its own layerwise sparsity estimate (a slot refilled
    with a new request must not inherit its predecessor's — or its
    neighbors' — attention statistics).
    """
    s = hoyer_sparsity(probs, axis=-1, where=where, n_valid=n_valid)
    return jnp.mean(s.reshape(s.shape[0], -1), axis=-1) if s.ndim > 1 else s


def allocate_budgets(sparsity: jax.Array, *, capacity: int, nominal: int,
                     min_budget: int, sink_len: int, recent_len: int) -> jax.Array:
    """Layerwise sparsity-aware budget allocation (spatial dimension).

    ``sparsity``: [L] per-layer Hoyer estimates *of one request*. Denser
    layers (low sparsity) receive proportionally larger budgets; the total
    budget is conserved at ``L * nominal`` — exactly, whenever that total is
    feasible within the per-layer floor/ceiling (``L*floor <= L*nominal <=
    L*ceil``) — so Lethe is iso-memory with a uniform allocator. When the
    total is infeasible every layer saturates at the violated bound (the
    nearest achievable allocation). Batched callers vmap over the batch axis
    (see ``allocate_budgets_batched``) so every serving slot gets its own
    allocation — budget conservation is per request, exactly as in the
    single-request paper setting.

    Returns int32 budgets [L], each in [min_budget, ~capacity).
    """
    sparsity = jnp.clip(sparsity.astype(jnp.float32), 0.0, 1.0)
    density = 1.0 - sparsity
    L = sparsity.shape[0]
    total = jnp.asarray(L * nominal, jnp.float32)
    weights = density / jnp.maximum(jnp.sum(density), _EPS)
    raw = weights * total
    floor = jnp.asarray(max(min_budget, sink_len + recent_len + 1), jnp.float32)
    ceil = jnp.asarray(int(capacity * 15 / 16), jnp.float32)
    budgets = jnp.clip(raw, floor, ceil)
    # Re-distribute clipping slack proportionally (one correction pass).
    slack = total - jnp.sum(budgets)
    room = jnp.where(slack >= 0, ceil - budgets, budgets - floor)
    room_total = jnp.maximum(jnp.sum(room), _EPS)
    budgets = jnp.clip(budgets + slack * room / room_total, floor, ceil)
    # Exact integer conservation: the proportional pass leaves float slack
    # and the int cast truncates, silently losing up to ~L tokens. Truncate,
    # then hand the integer residual out deterministically in layer order —
    # each layer absorbs as much of what is still outstanding as its
    # floor/ceiling room allows (an exclusive cumsum of room gives every
    # layer its share in one vectorised pass, no loop).
    floor_i = jnp.asarray(max(min_budget, sink_len + recent_len + 1), jnp.int32)
    ceil_i = jnp.asarray(int(capacity * 15 / 16), jnp.int32)
    b = jnp.clip(budgets.astype(jnp.int32), floor_i, ceil_i)
    resid = jnp.asarray(L * nominal, jnp.int32) - jnp.sum(b)
    room_up = ceil_i - b
    room_dn = b - floor_i
    give = jnp.clip(resid - (jnp.cumsum(room_up) - room_up), 0, room_up)
    take = jnp.clip(-resid - (jnp.cumsum(room_dn) - room_dn), 0, room_dn)
    return jnp.where(resid >= 0, b + give, b - take)


def allocate_budgets_batched(sparsity: jax.Array, **kw) -> jax.Array:
    """Per-request allocation over a batched sparsity estimate [L, B] ->
    budgets [L, B] (vmap of ``allocate_budgets`` over the slot axis)."""
    return jax.vmap(lambda sp: allocate_budgets(sp, **kw),
                    in_axes=1, out_axes=1)(sparsity)


def update_sparsity_ema(prev: jax.Array, observed: jax.Array,
                        ema: float) -> jax.Array:
    """Temporal smoothing of the layerwise sparsity estimate (shape-generic;
    [L] or per-slot [L, B] / [B] arrays)."""
    return ema * prev + (1.0 - ema) * observed
