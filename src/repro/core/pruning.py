"""Segmented attention-based token shrinking (Algorithm 1) and the unified
keep-rule/compaction machinery shared by Lethe and the re-implemented
baselines (H2O, StreamingLLM, PyramidKV).

Faithfulness note (see DESIGN.md §Faithfulness): the breakpoint is the first
segment cut-point where the score ratio v_top[0]/v_top[c] *exceeds* τ — the
evident intent of Eq. 4/Algorithm 1 ("the first segment where attention drops
sharply"), under which a larger ``sparse_ratio`` retains more tokens,
matching the paper's Table 6 ablation. If no cut ratio exceeds τ the layer
is attention-dense, no breakpoint exists, and pruning is delayed by doubling
L_evict (Algorithm 1 line 18).

Single-sort prune round (DESIGN.md §Perf): one descending-score ``argsort``
per row is computed in ``decide_row`` and threaded through every consumer —
the Algorithm-1 breakpoint ranking, the heavy-hitter top-k, and the capacity
backstop all derive their masks from that one order via cumulative-sum subset
ranking, and ``cache.compact`` packs survivors with a sort-free stable
partition. A prune round therefore performs exactly one O(C log C) sort per
row instead of four.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cache as cache_lib
from repro.core import rasr as rasr_lib
from repro.core.policy import (FULLKV, GKV, H2O, LAZYEVICTION, LETHE,
                               PYRAMIDKV, STREAMING, PolicyConfig)

_EPS = 1e-9
_NEG = -jnp.inf


class PruneDecision(NamedTuple):
    keep: jax.Array        # [B, C] bool
    breakpoint: jax.Array  # [B] int32; -1 = none found
    new_evict_at: jax.Array  # scalar int32
    order: jax.Array       # [B, C] int32 — slot ids in descending-score order


def _inverse_ranks(order: jax.Array) -> jax.Array:
    """[C] int32: rank of each slot in the descending-score ``order``."""
    C = order.shape[0]
    return jnp.zeros((C,), jnp.int32).at[order].set(
        jnp.arange(C, dtype=jnp.int32))


def _subset_ranks(order: jax.Array, subset: jax.Array) -> jax.Array:
    """Rank of each slot *within* ``subset`` under the descending-score
    ``order`` (number of higher-scored subset slots). Slots outside the
    subset get C. Replaces a per-subset argsort with two gathers + a cumsum.
    """
    C = order.shape[0]
    ss = subset[order]                              # subset flags, score-desc
    rank_sorted = jnp.cumsum(ss) - ss.astype(jnp.int32)   # exclusive cumsum
    ranks = jnp.zeros((C,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    return jnp.where(subset, ranks, C)


def algorithm1_breakpoint(scores: jax.Array, length: jax.Array, *,
                          n_segments: int, tau: float,
                          order: jax.Array | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """Algorithm 1 lines 1–11 for one batch row.

    ``scores``: [C] RASR scores (invalid slots must be -inf).
    ``length``: scalar valid count K (traced).
    ``order``: optional precomputed descending-score argsort of ``scores``
    (the prune round's single sort); computed here when omitted.
    Returns (breakpoint, salient_mask): breakpoint = -1 if no sharp drop;
    salient_mask [C] marks the top-`breakpoint` scored slots.
    """
    C = scores.shape[0]
    if order is None:
        order = jnp.argsort(-scores)                # descending
    top_values = scores[order]                      # sorted desc
    K = jnp.maximum(length, 1)
    d = jnp.arange(1, n_segments, dtype=jnp.int32)  # 1..D-1
    cuts = jnp.clip((K * d) // n_segments, 1, C - 1)  # [D-1]
    v_head = top_values[0]
    v_cut = top_values[cuts]                        # gather, [D-1]
    ratio = v_head / jnp.maximum(v_cut, _EPS)
    # Invalid (-inf) or non-positive cut values mean we're past the valid
    # prefix -> that cut certainly qualifies as "dropped".
    dropped = (ratio > tau) | (v_cut <= 0) | ~jnp.isfinite(v_cut)
    exists = jnp.any(dropped)
    first = jnp.argmax(dropped)                     # first True index
    breakpoint = jnp.where(exists, cuts[first], -1).astype(jnp.int32)

    # rank of each slot in score-descending order
    salient = _inverse_ranks(order) < jnp.maximum(breakpoint, 0)
    return breakpoint, salient


def _protected_mask(pos: jax.Array, cur_pos: jax.Array, *, sink_len: int,
                    recent_len: jax.Array) -> jax.Array:
    """Sink tokens (position < sink_len) and the trailing recency window."""
    sink = (pos >= 0) & (pos < sink_len)
    recent = pos >= (cur_pos - recent_len + 1)
    return sink | recent


def decide_row(scores: jax.Array, pos: jax.Array, length: jax.Array,
               cur_pos: jax.Array, *, policy: PolicyConfig,
               budget: jax.Array, evict_at: jax.Array,
               window: jax.Array | None = None,
               max_keep: jax.Array | None = None) -> PruneDecision:
    """Keep/evict decision for one layer, one batch row.

    ``scores``/``pos``: [C]; ``length``: scalar; ``budget``/``evict_at``:
    scalar traced; ``window``: optional sliding-attention window (slots whose
    position fell out of a local layer's window are dead for every policy).

    ``max_keep``: optional explicit occupancy ceiling (traced ok) for the
    capacity backstop — chunked prefill compresses its working buffer
    through this (the buffer is larger than the final cache, so the
    backstop's 15/16-of-C default would leave no room for the next chunk).
    The decode path never passes it.

    Performs exactly ONE argsort over C; every ranking below is derived from
    it (see module docstring).
    """
    C = scores.shape[0]
    valid = pos >= 0
    masked_scores = jnp.where(valid, scores, _NEG)
    recent_len = jnp.maximum(
        (budget.astype(jnp.float32) * policy.recent_ratio).astype(jnp.int32), 1)
    protected = _protected_mask(pos, cur_pos, sink_len=policy.sink_len,
                                recent_len=recent_len) & valid
    if window is not None:
        in_window = pos >= (cur_pos - window + 1)
        sink = (pos >= 0) & (pos < policy.sink_len)
        valid_w = valid & (in_window | sink)
    else:
        valid_w = valid

    kind = policy.kind
    # THE single sort of the prune round: slot ids by window-masked score,
    # descending, ties broken by slot index (stable argsort). G-KV ranks on
    # the age-normalised global score instead of the raw RASR accumulator
    # (the kind is static, so only one of the two rankings is ever traced).
    if kind == GKV:
        rank_base = rasr_lib.global_scores(masked_scores, pos, cur_pos)
    else:
        rank_base = masked_scores
    sort_scores = jnp.where(valid_w, rank_base, _NEG)
    order = jnp.argsort(-sort_scores).astype(jnp.int32)

    def _heavy_hitter_keep():
        # heavy-hitter top-k within (budget - protected count)
        n_protected = jnp.sum(protected & valid_w)
        n_hh = jnp.maximum(budget - n_protected, 0)
        candidates = valid_w & ~protected
        heavy = candidates & (_subset_ranks(order, candidates) < n_hh)
        return (protected | heavy) & valid_w

    breakpoint = jnp.full((), -1, jnp.int32)
    if kind == STREAMING:
        keep = protected & valid_w
        new_evict = budget
    elif kind in (H2O, PYRAMIDKV, GKV):
        keep = _heavy_hitter_keep()
        new_evict = budget
    elif kind == LAZYEVICTION:
        # Lagged eviction (arXiv 2506.15969). The observation phase is
        # encoded in the existing per-row (budget, evict_at) pair — no new
        # pytree leaf, so preemption/prefix-store/mesh snapshots carry it
        # for free. Trigger with evict_at <= budget = the row just reached
        # its budget: DEFER — keep everything and push evict_at out by
        # ``lag_window`` decode steps while the score EMA keeps observing
        # (recurring reasoning tokens regain rank). Trigger with
        # evict_at > budget = the observation window (or the 15/16·C
        # capacity backstop) expired: evict down to budget by the
        # heavy-hitter rule and re-arm the observation flag.
        observing = evict_at <= budget
        keep = jnp.where(observing, valid_w, _heavy_hitter_keep())
        lag = max(int(policy.lag_window), 1)
        new_evict = jnp.where(
            observing,
            jnp.clip(evict_at + lag, 1, policy.capacity),
            budget).astype(jnp.int32)
    elif kind == LETHE:
        bp, salient = algorithm1_breakpoint(
            sort_scores, length, n_segments=policy.n_segments,
            tau=policy.sparse_ratio, order=order)
        breakpoint = bp
        found = bp >= 0
        keep_found = (protected | salient) & valid_w
        keep_not = valid_w                      # delay pruning: keep all
        keep = jnp.where(found, keep_found, keep_not)
        new_evict = jnp.where(
            found,
            jnp.maximum(evict_at, bp + recent_len),
            evict_at * 2,
        )
        new_evict = jnp.clip(new_evict, 1, policy.capacity).astype(jnp.int32)
    else:  # FULLKV
        keep = valid
        new_evict = jnp.asarray(policy.capacity, jnp.int32)

    # Hard capacity backstop: if the keep-set would leave (almost) no room
    # for subsequent appends, truncate down to the layer *budget* (protected
    # slots win ties). This turns the Algorithm-1 "delay" path into a proper
    # multi-round sawtooth instead of riding at full capacity.
    cap_target = jnp.asarray(max(1, (C * 15) // 16), jnp.int32)
    if max_keep is not None:
        cap_target = jnp.minimum(cap_target,
                                 jnp.asarray(max_keep, jnp.int32))
    if kind != FULLKV:
        n_protected = jnp.sum(protected & valid_w)
        trunc_to = jnp.clip(jnp.maximum(budget, n_protected + 1), 1,
                            cap_target)
        n_keep = jnp.sum(keep)
        over = n_keep > cap_target
        # Protected kept slots rank first (in slot order — an f32 +1e30 prio
        # bump collapses their scores to a tie, so the historical ordering
        # is by index), then unprotected kept slots by descending score.
        pk = keep & protected
        uk = keep & ~protected
        n_pk = jnp.sum(pk)
        rank_pk = jnp.cumsum(pk) - pk.astype(jnp.int32)
        combined = jnp.where(pk, rank_pk, n_pk + _subset_ranks(order, uk))
        forced = keep & (combined < trunc_to)
        keep = jnp.where(over, forced, keep)
    return PruneDecision(keep=keep, breakpoint=breakpoint,
                         new_evict_at=new_evict, order=order)


def prune_layer(layer: cache_lib.KVCache, cur_pos: jax.Array, *,
                policy: PolicyConfig,
                window: jax.Array | None = None,
                force: bool = False) -> cache_lib.KVCache:
    """One pruning round for a layer slice (all batch rows).

    The trigger is PER ROW: a row prunes only when its own occupancy reaches
    min(its L_evict, capacity·15/16) — or unconditionally when ``force``.
    Rows below their threshold pass through bit-identically (their keep-set
    is the full valid set, under which ``compact`` is the identity gather),
    so one request's eviction schedule never depends on which neighbors
    share the batch. That row-independence is what lets the continuous-
    batching scheduler refill slots mid-decode and still reproduce
    per-request generation exactly. The surrounding ``lax.cond`` skips the
    whole round when no row triggered (the common decode step).

    ``cur_pos`` may be a scalar (lockstep decode) or [B] (continuous
    batching, one position per slot); ``layer.budget``/``layer.evict_at``
    are per-row [B].
    """
    C = layer.capacity
    if policy.kind == FULLKV:
        return layer

    B = layer.pos.shape[0]
    cur_b = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))
    trigger_at = jnp.minimum(layer.evict_at, (C * 15) // 16)      # [B]
    row_trig = (layer.length >= trigger_at) | force               # [B]

    def do_prune(l: cache_lib.KVCache) -> cache_lib.KVCache:
        dec = jax.vmap(
            lambda s, p, n, c, bg, ev: decide_row(
                s, p, n, c, policy=policy, budget=bg, evict_at=ev,
                window=window)
        )(l.score, l.pos, l.length, cur_b, l.budget, l.evict_at)
        keep = jnp.where(row_trig[:, None], dec.keep,
                         cache_lib.valid_mask(l.pos))
        compacted = cache_lib.compact(l, keep)
        new_evict = jnp.where(row_trig, dec.new_evict_at,
                              l.evict_at).astype(jnp.int32)
        # compact carried k/v/pos/score (and int8 dequant scales) with the
        # survivors; only the eviction schedule changes here.
        return dataclasses.replace(compacted, budget=l.budget, evict_at=new_evict,
                           sparsity=l.sparsity)

    if force:
        return do_prune(layer)

    return jax.lax.cond(jnp.any(row_trig), do_prune, lambda l: l, layer)


def compress_prefill_layer(layer: cache_lib.KVCache, cur_pos: jax.Array, *,
                           policy: PolicyConfig, max_keep: int,
                           window: jax.Array | None = None
                           ) -> cache_lib.KVCache:
    """Prefill-phase compression round for a chunked-prefill working buffer
    (one layer slice, all batch rows).

    Runs the same ``decide_row``/Algorithm-1 machinery as decode pruning
    but with an explicit occupancy ceiling ``max_keep`` (the *final* cache
    capacity, smaller than the working buffer): any row whose occupancy
    exceeds the ceiling is forced down — through the per-layer budget when
    the keep-set overflows — so prompts longer than capacity stream through
    a bounded buffer while the layerwise budget split stays faithful. Rows
    at or below the ceiling pass through bit-identically (keep = the full
    valid set, under which ``compact`` is the identity gather): a prompt
    that fits capacity is never perturbed by sharing a chunk program with
    one that does not.

    ``evict_at`` is left untouched — the Algorithm-1 eviction *schedule*
    belongs to decode and is (re)initialised at prefill finalize.
    """
    if policy.kind == FULLKV:
        return layer        # nothing can be evicted; caller rejects S > C

    B = layer.pos.shape[0]
    cur_b = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))
    row_over = layer.length > max_keep                      # [B]

    def do_compress(l: cache_lib.KVCache) -> cache_lib.KVCache:
        dec = jax.vmap(
            lambda s, p, n, c, bg, ev: decide_row(
                s, p, n, c, policy=policy, budget=bg, evict_at=ev,
                window=window, max_keep=jnp.asarray(max_keep, jnp.int32))
        )(l.score, l.pos, l.length, cur_b, l.budget, l.evict_at)
        keep = jnp.where(row_over[:, None], dec.keep,
                         cache_lib.valid_mask(l.pos))
        compacted = cache_lib.compact(l, keep)
        return dataclasses.replace(compacted, budget=l.budget, evict_at=l.evict_at,
                           sparsity=l.sparsity)

    return jax.lax.cond(jnp.any(row_over), do_compress, lambda l: l, layer)
