"""Recency-Aware Selective Retention (RASR) — the temporal half of Lethe.

Maintains the Eq. 5 per-token utility score during decode:

    s_t = γ · s_{t−1} + Σ_h Σ_i Σ_j A_h^{(t)}(i, j)

The attention mass Σ_h Σ_q A[b,h,q,k] per cached key is produced *inside* the
fused decode-attention kernel (per-key probability column-sums), and on the
decode hot path the EMA itself is applied in the kernel epilogue
(``ops.decode_attention_fused`` returns the updated scores directly), so
scoring adds no extra HBM pass at all. ``update_scores`` below is the
standalone form of the same arithmetic — the oracle the fused epilogue is
tested against, and the entry point for callers that obtain column-sums out
of band. Recency enters through the protected window in
``pruning.decide_row`` and through the decay γ, which gradually forgets
historically-hot tokens — exactly the paper's critique of pure H2O-style
accumulation ("overemphasis on historically high-attention tokens can mislead
later predictions").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import cache as cache_lib


def update_scores(layer: cache_lib.KVCache, probsum: jax.Array,
                  gamma: float) -> cache_lib.KVCache:
    """EMA-update RASR scores of a layer slice with this step's attention
    column-sums (``probsum``: [B, C], aligned with cache slots)."""
    valid = cache_lib.valid_mask(layer.pos)
    new_score = gamma * layer.score + probsum.astype(jnp.float32)
    new_score = jnp.where(valid, new_score, 0.0)
    return dataclasses.replace(layer, score=new_score)


def global_scores(score: jax.Array, pos: jax.Array,
                  cur_pos: jax.Array) -> jax.Array:
    """G-KV decide-time ranking: age-normalised global attention mass.

    G-KV (arXiv 2512.00504) accumulates *undecayed* attention mass — the
    γ=1 special case of the Eq. 5 EMA, so the kernel epilogue needs no new
    knob — but a raw running sum favours old tokens simply for having been
    scored on more decode steps. Dividing each token's accumulated mass by
    its observation age (steps since it entered the context) yields its mean
    per-step attention share, the global score the keep-rule ranks on.
    Invalid slots (pos < 0) are passed through; callers mask them anyway.
    """
    age = jnp.maximum(cur_pos - pos + 1, 1).astype(jnp.float32)
    return score / age


def prefill_scores(colsums: jax.Array, obs_window: int) -> jax.Array:
    """Initial RASR scores from prefill observation-window column sums.

    ``colsums``: [B, S] = Σ_h Σ_{q ∈ window} A[b,h,q,s]. Normalised by the
    window length so magnitudes are comparable with decode-step updates
    (each decode step adds Σ_h A ≈ H_q mass in total).
    """
    return colsums.astype(jnp.float32) * (1.0 / max(obs_window, 1))
