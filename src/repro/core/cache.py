"""Fixed-capacity slotted KV cache — the TPU-native form of Lethe's
dynamically-pruned cache.

GPU Lethe reallocates tensors on every eviction; under XLA that would
recompile. Here every layer owns a *static* buffer of ``capacity`` slots and
eviction is in-place compaction (mask -> argsort -> gather). All adaptivity
(occupancy, per-layer budget, the dynamic eviction threshold L_evict, the
layerwise sparsity estimate) is carried as traced values inside the pytree,
so data-dependent pruning decisions survive jit.

Layout (stacked over layers so models can ``lax.scan`` the stack):
  k, v      [L, B, H_kv, C, Dh]
  pos       [L, B, C]  int32, original token position; -1 = invalid slot
  score     [L, B, C]  f32, RASR accumulated attention mass (Eq. 5)
  length    [L, B]     int32, occupancy; valid slots are [0, length)
  budget    [L, B]     int32, spatial-allocator target (Sec. "Spatial ...")
  evict_at  [L, B]     int32, dynamic L_evict threshold (Algorithm 1)
  sparsity  [L, B]     f32, layerwise Hoyer sparsity EMA
  k_scale,  [L, B, H_kv, C]  f32 per-(token, kv-head) dequant scales; ONLY
  v_scale              present when the policy's ``kv_format`` is "int8"
                       (k/v then hold int8 payloads); None on the dense path

Quantized mode (``kv_format="int8"``, DESIGN.md §Quantization): K/V payloads
are symmetric-int8 per (token, kv-head) blocks — q = round(x·127/amax(|x|)),
one f32 scale per Dh-vector — quantised *on write* in every producer
(``append_token``, ``append_chunk``, ``fill_from_prefill_slotted``) and
dequantised *inside the attention kernels*, never as a host-visible pass.
The scales are ordinary cache leaves with batch at axis 1 and the slot axis
last, so the entire slot/prune machinery below (masked selects, the
stable-partition ``compact``, slot refill) moves them with their tokens
without any quantization-aware code.

``budget``/``evict_at``/``sparsity`` carry a batch axis because under
continuous batching each slot hosts a *different request*: one row's
Algorithm-1 eviction schedule, sparsity profile, and per-layer budget must
not leak into a neighbor admitted at a different time. Every field therefore
has batch at axis 1, which is what makes the slot-refill ops below a single
uniform masked select over any decode-state pytree.

Invariants: valid slots are packed at the front in increasing ``pos`` order;
invalid slots hold pos = -1 and score = 0.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import register_dataclass

from repro.core.policy import PolicyConfig


def _onehot_append() -> bool:
    """Append via a one-hot masked select (default) instead of per-row
    dynamic_update_slice. The scatter form makes GSPMD replicate the whole
    sharded cache around the write (§Perf, command-r decode_32k:
    ~10.7 GB/step of involuntary all-gather); the masked select is elementwise
    and preserves any sharding. REPRO_ONEHOT_APPEND=0 restores the scatter
    (the paper-faithful §Perf baseline)."""
    return os.environ.get("REPRO_ONEHOT_APPEND", "1") == "1"


@register_dataclass
@dataclass
class KVCache:
    k: jax.Array
    v: jax.Array
    pos: jax.Array
    score: jax.Array
    length: jax.Array
    budget: jax.Array
    evict_at: jax.Array
    sparsity: jax.Array
    # int8 mode only: per-(token, kv-head) dequant scales [..., H_kv, C].
    # None on the dense path — the pytree then flattens to the exact same
    # eight leaves as before the quantization refactor (bit-identity).
    k_scale: jax.Array | None = None
    v_scale: jax.Array | None = None

    @property
    def n_layers(self) -> int:
        return self.k.shape[0]

    @property
    def capacity(self) -> int:
        return self.k.shape[-2]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def layer(self, l: int) -> "KVCache":
        return jax.tree.map(lambda x: x[l], self)

    def memory_breakdown(self) -> dict:
        """Physical bytes per leaf group: K/V payloads, dequant scales, and
        score/position/metadata — what actually occupies HBM, so benchmark
        JSONs can record real bytes rather than just slot capacity."""
        def nbytes(*xs):
            return sum(x.size * x.dtype.itemsize for x in xs
                       if x is not None)
        return {
            "kv_payload_bytes": nbytes(self.k, self.v),
            "scale_bytes": nbytes(self.k_scale, self.v_scale),
            "score_bytes": nbytes(self.score),
            "meta_bytes": nbytes(self.pos, self.length, self.budget,
                                 self.evict_at, self.sparsity),
        }

    def memory_bytes(self) -> int:
        return sum(self.memory_breakdown().values())


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-vector int8 quantization over the trailing (Dh) axis.

    x [..., Dh] -> (q int8 [..., Dh], scale f32 [...]): q = round(x / scale)
    with scale = amax(|x|)/127 (1.0 for all-zero vectors, so empty slots
    round-trip to exact zeros). Worst-case elementwise error is scale/2 =
    amax/254 — the per-head error bound the tests assert.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Inverse of ``quantize_kv``: q [..., Dh] int8, scale [...] f32."""
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def init_kv_payload(shape: tuple, *, kv_format: str, dtype
                    ) -> tuple[jax.Array, jax.Array,
                               jax.Array | None, jax.Array | None]:
    """Zero-initialised (k, v, k_scale, v_scale) payload leaves for a
    slotted buffer of shape [..., C, Dh] — THE one spelling of the
    kv_format -> dtype/scale-init rule, shared by the decode cache and the
    chunked-prefill working buffer. int8 mode gives int8 payloads with
    unit f32 scales; the k/v scale arrays are deliberately distinct (a
    shared buffer would be donated twice by the slot-refill jits, which
    XLA rejects)."""
    quantized = kv_format == "int8"
    kv_dtype = jnp.int8 if quantized else dtype

    def scale0():
        return jnp.ones(shape[:-1], jnp.float32) if quantized else None
    return (jnp.zeros(shape, kv_dtype), jnp.zeros(shape, kv_dtype),
            scale0(), scale0())


def init_cache(*, n_layers: int, batch: int, n_kv_heads: int, capacity: int,
               d_head: int, policy: PolicyConfig,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (n_layers, batch, n_kv_heads, capacity, d_head)
    nominal = min(policy.nominal_budget, capacity)
    k, v, k_scale, v_scale = init_kv_payload(
        shape, kv_format=getattr(policy, "kv_format", "bf16"), dtype=dtype)
    return KVCache(
        k=k, v=v,
        pos=jnp.full((n_layers, batch, capacity), -1, jnp.int32),
        score=jnp.zeros((n_layers, batch, capacity), jnp.float32),
        length=jnp.zeros((n_layers, batch), jnp.int32),
        budget=jnp.full((n_layers, batch), nominal, jnp.int32),
        evict_at=jnp.full((n_layers, batch), nominal, jnp.int32),
        sparsity=jnp.zeros((n_layers, batch), jnp.float32),
        k_scale=k_scale, v_scale=v_scale,
    )


# --------------------------------------------------------------------------
# Per-slot lifecycle operations (full [L, B, ...] stacks) — the refill
# primitives of continuous batching. Both are elementwise masked selects
# (same sharding-preserving idiom as the one-hot append), so rows other than
# ``slot`` pass through bit-identically and the ops compose with donation.
# --------------------------------------------------------------------------

def _slots_mask(n_slots: int, slots) -> tuple[jax.Array, jax.Array]:
    """(sel [B] bool, idx [B] int32): which batch rows are named in
    ``slots`` (scalar or [k] int32; -1 entries are no-ops) and, for each
    selected row, the index into ``slots`` that named it."""
    s = jnp.atleast_1d(jnp.asarray(slots, jnp.int32))
    eq = jnp.arange(n_slots, dtype=jnp.int32)[:, None] == s[None, :]  # [B,k]
    return eq.any(axis=1), jnp.argmax(eq, axis=1)


def tree_update_slots(state, slots, rows_state):
    """Overwrite the batch rows named in ``slots`` (scalar or [k]; -1 =
    no-op) of a decode-state pytree with the corresponding rows of
    ``rows_state`` (batch axis of size k at axis 1) — the admission
    primitive, batched so one call admits a whole group of requests.

    Works for *any* model family's decode state — slotted ``KVCache``,
    rwkv6's recurrence matrices, rglru's hybrid dict — because every decode
    state leaf in this codebase is laid out ``[L, B, ...]``.
    """
    def upd(leaf, rows):
        sel, idx = _slots_mask(leaf.shape[1], slots)
        gathered = jnp.take(rows.astype(leaf.dtype), idx, axis=1)
        mask = sel.reshape((1, leaf.shape[1]) + (1,) * (leaf.ndim - 2))
        return jnp.where(mask, gathered, leaf)
    return jax.tree.map(upd, state, rows_state)


def tree_update_slot(state, slot, row_state):
    """Single-slot form of ``tree_update_slots`` (``row_state`` batch 1)."""
    return tree_update_slots(state, slot, row_state)


def tree_reset_slot(state, slots):
    """Retire the batch rows named in ``slots`` (scalar or [k] int32, -1 =
    no-op) of an arbitrary decode-state pytree. ``KVCache`` subtrees get the
    full empty-slot treatment (``reset_slot``); plain recurrence leaves
    (rwkv6 wkv matrices, rglru conv state, whisper cross-K/V) are zeroed."""
    def zero_rows(sub):
        def upd(leaf):
            sel, _ = _slots_mask(leaf.shape[1], slots)
            mask = sel.reshape((1, leaf.shape[1]) + (1,) * (leaf.ndim - 2))
            return jnp.where(mask, jnp.zeros((), leaf.dtype), leaf)
        return jax.tree.map(upd, sub)

    def one(sub):
        if isinstance(sub, KVCache):
            return reset_slot(sub, slots)
        return zero_rows(sub)
    return jax.tree.map(one, state, is_leaf=lambda x: isinstance(x, KVCache))


def reset_slot(cache: KVCache, slots) -> KVCache:
    """Retire the batch rows named in ``slots`` (scalar or [k]; -1 = no-op)
    across all layers: K/V and scores zeroed, positions invalidated,
    occupancy 0. ``evict_at`` is parked at capacity so an empty (or dead,
    still-decoding) slot cannot spuriously trigger a prune round before its
    next admission overwrites the row's budget state. Rows not named pass
    through bit-identically.
    """
    C = cache.capacity
    sel, _ = _slots_mask(cache.k.shape[1], slots)

    def fill(leaf, value):
        mask = sel.reshape((1, leaf.shape[1]) + (1,) * (leaf.ndim - 2))
        return jnp.where(mask, jnp.asarray(value, leaf.dtype), leaf)

    return KVCache(
        k=fill(cache.k, 0), v=fill(cache.v, 0), pos=fill(cache.pos, -1),
        score=fill(cache.score, 0.0), length=fill(cache.length, 0),
        budget=fill(cache.budget, C), evict_at=fill(cache.evict_at, C),
        sparsity=fill(cache.sparsity, 0.0),
        k_scale=(fill(cache.k_scale, 1.0) if cache.quantized else None),
        v_scale=(fill(cache.v_scale, 1.0) if cache.quantized else None))


def insert_slot(cache: KVCache, slot, row: KVCache) -> KVCache:
    """Admit a freshly prefilled single-request cache (batch axis 1) into
    batch row ``slot`` of a live cache. Capacities must match; all other
    rows — K/V, positions, RASR scores, budgets, eviction thresholds —
    pass through untouched."""
    assert row.capacity == cache.capacity, (row.capacity, cache.capacity)
    return tree_update_slot(cache, slot, row)


# Donated forms of the slot ops (module-level so `models/api.py` and
# `serving/engine.py` share one jit cache): the live state aliases
# input→output and slot turnover mutates the standing allocation in place.
update_slots_donated = jax.jit(tree_update_slots, donate_argnums=(0,))
reset_slots_donated = jax.jit(tree_reset_slot, donate_argnums=(0,))


# --------------------------------------------------------------------------
# Preemption-to-host: snapshot a slot's rows off-device and re-admit them
# later, bit-exactly. A snapshot is the complete per-request state — K/V
# payloads (bf16 or int8 + dequant scales), positions, RASR scores, and the
# per-row budget/evict_at/sparsity machinery — because every decode-state
# leaf is laid out [L, B, ...]; nothing about a request lives outside its
# batch row.
# --------------------------------------------------------------------------

def tree_extract_slots(state, slots):
    """Copy the batch rows named in ``slots`` ([k] int) of a decode-state
    pytree to HOST memory: a numpy pytree with batch axis k at axis 1,
    exactly the ``rows_state`` shape that ``tree_update_slots`` re-admits.

    The copy preserves bit patterns (ml_dtypes bfloat16 / int8 payloads and
    f32 scales round-trip exactly), so extract -> insert is the identity on
    the named rows — the preemption guarantee the serving front door's
    differential tests assert.
    """
    ids = np.asarray(slots, np.int32).reshape(-1)
    return jax.tree.map(lambda leaf: np.asarray(leaf)[:, ids], state)


def tree_extract_slot(state, slot: int):
    """Single-slot form of ``tree_extract_slots`` (batch axis of 1)."""
    return tree_extract_slots(state, [slot])


def tree_insert_slots(state, slots, rows_state):
    """Re-admit host-side rows (from ``tree_extract_slots``) into the batch
    rows named in ``slots`` — the donated masked insert, so every other
    slot passes through bit-identically and ``state`` is consumed."""
    rows = jax.tree.map(jnp.asarray, rows_state)
    return update_slots_donated(state, jnp.asarray(slots, jnp.int32), rows)


# Aliases under the serving-facing names (ISSUE 6): ``extract_slot`` /
# ``insert_slot`` round-trip one request through host RAM.
extract_slots = tree_extract_slots
extract_slot = tree_extract_slot
insert_slots = tree_insert_slots


def quantize_cache(cache: KVCache) -> KVCache:
    """Dense -> int8 block-scaled conversion of a (possibly live) cache:
    the degradation-ladder rung that trades dequant error for halved KV
    bytes under sustained overload. Per-(token, kv-head) symmetric
    quantization, same layout ``init_kv_payload`` builds; empty slots
    (zero vectors) get unit scales and round-trip to exact zeros. No-op on
    an already-quantized cache. Score/position/budget state is untouched —
    only the payload representation degrades."""
    if cache.quantized:
        return cache
    qk, sk = quantize_kv(cache.k)
    qv, sv = quantize_kv(cache.v)
    return KVCache(k=qk, v=qv, pos=cache.pos, score=cache.score,
                   length=cache.length, budget=cache.budget,
                   evict_at=cache.evict_at, sparsity=cache.sparsity,
                   k_scale=sk, v_scale=sv)


def tree_quantize(state):
    """Apply ``quantize_cache`` to every KVCache subtree of a decode state
    (non-cache leaves — recurrence matrices, conv state — pass through)."""
    return jax.tree.map(
        lambda s: quantize_cache(s) if isinstance(s, KVCache) else s,
        state, is_leaf=lambda x: isinstance(x, KVCache))


# jitted, NOT donated: the int8 leaves cannot alias the bf16 input buffers
# (different dtypes), so migration transiently holds both representations.
quantize_tree_jit = jax.jit(tree_quantize)


# --------------------------------------------------------------------------
# Single-layer slice operations (no leading L axis) — used inside layer scans.
# --------------------------------------------------------------------------

def valid_mask(pos: jax.Array) -> jax.Array:
    """[B, C] bool — slot holds a live token."""
    return pos >= 0


def append_token(layer: KVCache, k_new: jax.Array, v_new: jax.Array,
                 cur_pos: jax.Array, init_score: float) -> KVCache:
    """Append one decoded token's K/V to a layer slice.

    ``k_new``/``v_new``: [B, H_kv, Dh]; written at each row's ``length`` slot.
    If a row is (pathologically) full the write clamps onto the last slot —
    the pruning trigger guarantees this cannot drop a protected token.
    """
    B, Hkv, C, Dh = layer.k.shape
    idx = jnp.minimum(layer.length, C - 1)  # [B]
    pos_val = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))

    ks = vs = None
    if layer.quantized:          # quantize-on-write: one scale per kv-head
        k_new, ks = quantize_kv(k_new)       # [B, Hkv, Dh] int8, [B, Hkv]
        v_new, vs = quantize_kv(v_new)

    if _onehot_append():
        hot = (jnp.arange(C, dtype=jnp.int32)[None, :] == idx[:, None])
        k = jnp.where(hot[:, None, :, None],
                      k_new.astype(layer.k.dtype)[:, :, None, :], layer.k)
        v = jnp.where(hot[:, None, :, None],
                      v_new.astype(layer.v.dtype)[:, :, None, :], layer.v)
        pos = jnp.where(hot, pos_val[:, None], layer.pos)
        score = jnp.where(hot, jnp.float32(init_score), layer.score)
        length = jnp.minimum(layer.length + 1, C)
        k_scale = v_scale = None
        if layer.quantized:
            k_scale = jnp.where(hot[:, None, :], ks[:, :, None],
                                layer.k_scale)
            v_scale = jnp.where(hot[:, None, :], vs[:, :, None],
                                layer.v_scale)
        return KVCache(k=k, v=v, pos=pos, score=score, length=length,
                       budget=layer.budget, evict_at=layer.evict_at,
                       sparsity=layer.sparsity,
                       k_scale=k_scale, v_scale=v_scale)

    def write_row(buf, upd, i):
        return jax.lax.dynamic_update_slice(buf, upd[:, None, :], (0, i, 0))

    k = jax.vmap(write_row)(layer.k, k_new.astype(layer.k.dtype), idx)
    v = jax.vmap(write_row)(layer.v, v_new.astype(layer.v.dtype), idx)

    def write_scalar(buf, val, i):
        return jax.lax.dynamic_update_slice(buf, val[None], (i,))

    pos = jax.vmap(write_scalar)(layer.pos, pos_val, idx)
    score = jax.vmap(write_scalar)(
        layer.score, jnp.full((B,), init_score, jnp.float32), idx)
    length = jnp.minimum(layer.length + 1, C)
    k_scale = v_scale = None
    if layer.quantized:
        def write_head(buf, val, i):   # buf [Hkv, C], val [Hkv]
            return jax.lax.dynamic_update_slice(buf, val[:, None], (0, i))
        k_scale = jax.vmap(write_head)(layer.k_scale, ks, idx)
        v_scale = jax.vmap(write_head)(layer.v_scale, vs, idx)
    return KVCache(k=k, v=v, pos=pos, score=score, length=length,
                   budget=layer.budget, evict_at=layer.evict_at,
                   sparsity=layer.sparsity,
                   k_scale=k_scale, v_scale=v_scale)


def append_chunk(layer: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos_new: jax.Array, init_score: float = 0.0) -> KVCache:
    """Append one prefill chunk's K/V to a layer slice (chunked prefill).

    ``k_new``/``v_new``: [B, Hkv, n, Dh]; ``pos_new``: [n] absolute token
    positions (shared across rows — a chunk spans the same prompt span for
    every request in the admission group). Chunk token j lands at each
    row's slot ``length + j`` — the multi-token form of ``append_token``,
    written as the same elementwise masked select so it donates/shards
    identically and rows at different (post-compression) occupancies append
    independently. Rows must have ``length + n <= capacity``; the chunked
    prefill driver guarantees that by compressing before the next chunk.
    """
    B, Hkv, C, Dh = layer.k.shape
    n = k_new.shape[2]
    ks = vs = None
    if layer.quantized:          # quantize-on-write, per (token, kv-head)
        k_new, ks = quantize_kv(k_new)       # int8, scales [B, Hkv, n]
        v_new, vs = quantize_kv(v_new)
    # chunk-relative target index of each slot: slot c takes chunk token
    # (c - length) when that lies in [0, n)
    rel = (jnp.arange(C, dtype=jnp.int32)[None, :]
           - layer.length[:, None])                          # [B, C]
    hit = (rel >= 0) & (rel < n)
    take = jnp.clip(rel, 0, n - 1)
    k = jnp.where(hit[:, None, :, None],
                  jnp.take_along_axis(k_new.astype(layer.k.dtype),
                                      take[:, None, :, None], axis=2),
                  layer.k)
    v = jnp.where(hit[:, None, :, None],
                  jnp.take_along_axis(v_new.astype(layer.v.dtype),
                                      take[:, None, :, None], axis=2),
                  layer.v)
    pos = jnp.where(hit, jnp.asarray(pos_new, jnp.int32)[take], layer.pos)
    score = jnp.where(hit, jnp.float32(init_score), layer.score)
    length = jnp.minimum(layer.length + n, C)
    k_scale = v_scale = None
    if layer.quantized:
        k_scale = jnp.where(hit[:, None, :],
                            jnp.take_along_axis(ks, take[:, None, :],
                                                axis=2), layer.k_scale)
        v_scale = jnp.where(hit[:, None, :],
                            jnp.take_along_axis(vs, take[:, None, :],
                                                axis=2), layer.v_scale)
    return KVCache(k=k, v=v, pos=pos, score=score, length=length,
                   budget=layer.budget, evict_at=layer.evict_at,
                   sparsity=layer.sparsity,
                   k_scale=k_scale, v_scale=v_scale)


def compact(layer: KVCache, keep: jax.Array) -> KVCache:
    """Evict all slots where ``keep`` [B, C] is False, packing survivors to
    the front in increasing position order (static shapes throughout).

    Sort-free: because valid slots are already packed in increasing ``pos``
    order (the cache invariant — every writer appends at ``length`` or goes
    through this function), packing survivors is a *stable partition* by
    ``keep``, computed with two cumulative sums and a scatter instead of an
    O(C log C) argsort. The prune round's only sort is the score ranking in
    ``pruning.decide_row``.
    """
    B, Hkv, C, Dh = layer.k.shape
    live = keep & valid_mask(layer.pos)
    n_kept = jnp.sum(live, axis=-1).astype(jnp.int32)       # [B]
    # Stable partition: kept slot i moves to (number of kept slots before i),
    # dropped slot i moves to n_kept + (number of dropped slots before i).
    kept_before = (jnp.cumsum(live, axis=-1, dtype=jnp.int32)
                   - live.astype(jnp.int32))
    drop_before = (jnp.cumsum(~live, axis=-1, dtype=jnp.int32)
                   - (~live).astype(jnp.int32))
    target = jnp.where(live, kept_before, n_kept[:, None] + drop_before)
    # Invert the permutation: order[b, target[b, c]] = c, i.e. the gather
    # index list equivalent to the old argsort-by-position.
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    src = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    order = jnp.zeros((B, C), jnp.int32).at[rows, target].set(src)

    pos = jnp.take_along_axis(jnp.where(live, layer.pos, -1), order, axis=-1)
    score = jnp.take_along_axis(jnp.where(live, layer.score, 0.0), order,
                                axis=-1)
    gather_kv = jax.vmap(lambda buf, o: jnp.take(buf, o, axis=1))  # over B
    k = gather_kv(layer.k, order)
    v = gather_kv(layer.v, order)
    k_scale = v_scale = None
    if layer.quantized:     # scales ride the same permutation as their slot
        k_scale = jnp.take_along_axis(layer.k_scale, order[:, None, :],
                                      axis=-1)
        v_scale = jnp.take_along_axis(layer.v_scale, order[:, None, :],
                                      axis=-1)
    return KVCache(k=k, v=v, pos=pos, score=score, length=n_kept,
                   budget=layer.budget, evict_at=layer.evict_at,
                   sparsity=layer.sparsity,
                   k_scale=k_scale, v_scale=v_scale)


def fill_from_prefill_slotted(k: jax.Array, v: jax.Array, pos: jax.Array,
                              score: jax.Array, length: jax.Array, *,
                              capacity: int,
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None
                              ) -> tuple[jax.Array, jax.Array, jax.Array,
                                         jax.Array, jax.Array,
                                         jax.Array | None,
                                         jax.Array | None]:
    """Initialise a layer slice from a *slotted* prefill working set
    (k/v [B, Hkv, E, Dh], pos/score [B, E], length [B], E >= capacity).

    Keeps the ``capacity`` highest-priority slots (invalid slots carry -inf
    priority; the last live token — the query's own position — is pinned),
    then packs them in slot order. When at most ``capacity`` slots are live
    (every chunked prefill, whose compression round maintains that bound)
    the selection is an identity gather of the packed prefix — bit-exact.
    The priority path is the whole-prompt S > capacity case.

    ``k_scale``/``v_scale`` [B, Hkv, E]: int8 dequant scales, gathered with
    the same index list so each surviving token keeps its own scale
    (quantize-on-write happens upstream; this fill is pure data movement).

    Returns (k, v, pos, score, length, k_scale, v_scale) with the static
    ``capacity`` axis (scales are None on the dense path).
    """
    B, Hkv, E, Dh = k.shape
    if E == capacity:
        return k, v, pos, score, jnp.minimum(length, capacity), \
            k_scale, v_scale
    valid = pos >= 0
    prio = jnp.where(valid, score.astype(jnp.float32), -jnp.inf)
    last = jnp.maximum(length - 1, 0)
    prio = prio.at[jnp.arange(B), last].set(
        jnp.where(length > 0, jnp.inf, prio[jnp.arange(B), last]))
    _, top_idx = jax.lax.top_k(prio, capacity)
    top_idx = jnp.sort(top_idx, axis=-1)             # temporal (slot) order
    take = jax.vmap(lambda buf, o: jnp.take(buf, o, axis=1))
    k_c = take(k, top_idx)
    v_c = take(v, top_idx)
    pos_c = jnp.take_along_axis(pos, top_idx, axis=-1)
    score_c = jnp.take_along_axis(score.astype(jnp.float32), top_idx,
                                  axis=-1)
    ks_c = vs_c = None
    if k_scale is not None:
        ks_c = jnp.take_along_axis(k_scale, top_idx[:, None, :], axis=-1)
        vs_c = jnp.take_along_axis(v_scale, top_idx[:, None, :], axis=-1)
    return k_c, v_c, pos_c, score_c, jnp.minimum(length, capacity), \
        ks_c, vs_c


# (The old dense ``fill_from_prefill`` is gone: every prefill path now
# routes through ``fill_from_prefill_slotted`` inside the shared
# ``chunked.finalize_pipeline`` program.)
