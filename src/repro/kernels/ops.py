"""Jit'd public wrappers around the attention kernels.

``impl`` selection:
  "pallas"    — pl.pallas_call targeting TPU (the production path).
  "interpret" — same kernel body, executed via Pallas interpret mode
                (CPU correctness validation; what the tests sweep).
  "ref"       — pure-jnp oracle. Used on CPU runs and inside the multi-pod
                dry-run lowering so cost_analysis reflects XLA-native
                attention (FLOP/byte-equivalent to the kernel).
  "auto"      — "pallas" on TPU backends, "ref" elsewhere.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_impl
from repro.kernels.decode_attention import (GLOBAL_WINDOW,
                                            decode_attention_pallas,
                                            live_lengths)
from repro.kernels.flash_prefill import flash_prefill_pallas

_DEFAULT = {"impl": "auto"}


def _ambient_mesh():
    """The mesh a serving engine activated with ``with mesh:`` around this
    trace, or None. Safe to branch on inside jitted code: the trace cache
    keys on the ambient mesh context, so a mesh-bound engine and a no-mesh
    engine never share a traced program (verified by the mesh battery)."""
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    if m.empty or "model" not in m.axis_names:
        return None
    return m


def _decode_fused_shard_map(mesh, q, k, v, pos, cur, score, lens, win, *,
                            gamma, softcap, scale, k_scale, v_scale,
                            interpret):
    """Tensor-parallel decode attention: the Pallas kernel under shard_map
    over kv-heads, with the partial-softmax all-reduce epilogue.

    Each shard runs the early-exit kernel over its local Hkv/tp heads —
    every (head, group) softmax row is complete locally (softmax normalises
    over C, which is unsharded), so the attention *output* needs no
    communication at all (the Megatron wo all-reduce downstream covers it).
    Only the RASR bookkeeping crosses shards: Eq. 2's column-sums aggregate
    over ALL heads, so each shard's ``probsum`` is a partial sum -> one
    [B, C] f32 psum over ``model``, after which the Eq. 5 EMA
    (γ·score + probsum, zeroed on invalid slots) is applied to the
    replicated score — exactly ``decode_attention_fused_ref``'s arithmetic.
    The kernel itself runs with gamma=0 over a zero score so its fused
    epilogue emits raw (local) column-sums.
    """
    from jax.experimental.shard_map import shard_map

    daxes = tuple(a for a in mesh.axis_names if a != "model")
    dsz = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    B = q.shape[0]
    data_ok = dsz > 1 and B >= dsz and B % dsz == 0
    b = (daxes if len(daxes) > 1 else daxes[0]) if data_ok else None
    from jax.sharding import PartitionSpec as P
    qs = P(b, "model", None)
    kvs = P(b, "model", None, None)
    vec = P(b, None)
    row = P(b)
    quant = k_scale is not None

    def body(q, k, v, pos, score, lens, cur, win, *scales):
        ks, vs = scales if quant else (None, None)
        out, ps_local, _, _ = decode_attention_pallas(
            q, k, v, pos, jnp.zeros_like(score), lens, cur, win,
            scale=scale, softcap=softcap, gamma=0.0, interpret=interpret,
            k_scale=ks, v_scale=vs)
        probsum = jax.lax.psum(ps_local, "model")
        new_score = jnp.where(pos >= 0,
                              gamma * score.astype(jnp.float32) + probsum,
                              0.0)
        return out, probsum, new_score

    in_specs = [qs, kvs, kvs, vec, vec, row, row, P()]
    args = [q, k, v, pos, score, lens, cur, win]
    if quant:
        in_specs += [P(b, "model", None)] * 2
        args += [k_scale, v_scale]
    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=(qs, vec, vec), check_rep=False)
    return fn(*args)


def set_default_impl(impl: str) -> None:
    assert impl in ("auto", "pallas", "interpret", "ref")
    _DEFAULT["impl"] = impl


def _resolve(impl: str | None) -> str:
    impl = impl or _DEFAULT["impl"]
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def decode_attention_fused(q: jax.Array, k: jax.Array, v: jax.Array,
                           pos: jax.Array, cur_pos, score: jax.Array, *,
                           gamma: float, window=None,
                           softcap: float | None = None,
                           scale: float | None = None,
                           lengths: jax.Array | None = None,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           impl: str | None = None
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked single-token attention over a slotted cache, fused with the
    RASR bookkeeping: emits the per-key probability column-sums AND the
    Eq. 5 EMA-updated scores in one pass (the decode hot path).

    q [B,Hq,Dh]; k,v [B,Hkv,C,Dh]; pos [B,C] (−1 = invalid); score [B,C].
    ``lengths`` [B]: live-length bound for the kernel's occupancy-adaptive
    early exit (derived from ``pos`` when omitted; pass ``KVCache.length``
    on the hot path to skip the reduction). ``window`` may be a traced
    scalar (per-layer local/global scans). ``k_scale``/``v_scale``
    [B,Hkv,C]: int8 block-scaled cache payloads, dequantised inside the
    kernel (pass ``KVCache.k_scale``/``v_scale`` — the int8 hot path).
    Returns (out [B,Hq,Dh], probsum [B,C], new_score [B,C])."""
    impl = _resolve(impl)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    mesh = _ambient_mesh()
    if mesh is not None and mesh.shape["model"] > 1 and impl != "ref":
        # Mesh-sharded serving: wrap the kernel in shard_map over kv-heads
        # when the head counts divide; otherwise fall back to the jnp
        # oracle and let GSPMD partition it (Pallas-under-shard_map needs
        # an exact head split).
        tp = mesh.shape["model"]
        B, Hq, _ = q.shape
        Hkv = k.shape[1]
        if Hkv % tp == 0 and Hq % tp == 0:
            lens = lengths if lengths is not None else live_lengths(pos)
            win = jnp.asarray(GLOBAL_WINDOW if window is None else window,
                              jnp.int32)
            cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))
            return _decode_fused_shard_map(
                mesh, q, k, v, pos, cur, score, lens, win, gamma=gamma,
                softcap=softcap, scale=scale, k_scale=k_scale,
                v_scale=v_scale, interpret=(impl == "interpret"))
        impl = "ref"
    if impl == "ref":
        return ref_impl.decode_attention_fused_ref(
            q, k, v, pos, cur_pos, score, gamma=gamma, window=window,
            softcap=softcap, scale=scale, k_scale=k_scale, v_scale=v_scale)
    lens = lengths if lengths is not None else live_lengths(pos)
    win = GLOBAL_WINDOW if window is None else window
    out, probsum, new_score, _ = decode_attention_pallas(
        q, k, v, pos, score, lens, cur_pos, win, scale=scale,
        softcap=softcap, gamma=gamma, interpret=(impl == "interpret"),
        k_scale=k_scale, v_scale=v_scale)
    return out, probsum, new_score


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, cur_pos, *, window=None,
                     softcap: float | None = None, scale: float | None = None,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None,
                     impl: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Masked single-token attention over a slotted cache + RASR column-sums
    (score-free form, e.g. whisper's static cross-attention cache).

    q [B,Hq,Dh]; k,v [B,Hkv,C,Dh]; pos [B,C] (−1 = invalid).
    Returns (out [B,Hq,Dh], probsum [B,C])."""
    impl = _resolve(impl)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if impl == "ref":
        return ref_impl.decode_attention_ref(
            q, k, v, pos, cur_pos, window=window, softcap=softcap,
            scale=scale, k_scale=k_scale, v_scale=v_scale)
    out, probsum, _ = decode_attention_fused(
        q, k, v, pos, cur_pos, jnp.zeros(pos.shape, jnp.float32),
        gamma=0.0, window=window, softcap=softcap, scale=scale,
        k_scale=k_scale, v_scale=v_scale, impl=impl)
    return out, probsum


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      softcap: float | None = None,
                      scale: float | None = None, q_offset: int = 0,
                      impl: str | None = None) -> jax.Array:
    """Flash prefill forward. q [B,Hq,S,Dh]; k,v [B,Hkv,T,Dh].
    Returns out [B,Hq,S,Dh] (LSE is an internal detail here)."""
    impl = _resolve(impl)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if impl == "ref":
        import os
        chunk = int(os.environ.get("REPRO_PREFILL_CHUNKED", "0"))
        if chunk and q_offset == 0 and q.shape[2] > chunk:
            return ref_impl.prefill_attention_chunked_ref(
                q, k, v, chunk=chunk, causal=causal, window=window,
                softcap=softcap, scale=scale)
        out, _ = ref_impl.prefill_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset)
        return out
    out, _ = flash_prefill_pallas(
        q, k, v, scale=scale, softcap=softcap, causal=causal, window=window,
        q_offset=q_offset, interpret=(impl == "interpret"))
    return out


def chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    k_pos: jax.Array, q_start, *, window=None,
                    softcap: float | None = None,
                    scale: float | None = None,
                    contiguous_offset: int | None = None,
                    k_scale: jax.Array | None = None,
                    v_scale: jax.Array | None = None,
                    impl: str | None = None) -> jax.Array:
    """Chunk-of-queries attention over a slotted cache (chunked prefill).

    q [B,Hq,n,Dh] at absolute positions ``q_start..q_start+n-1`` (traced
    ok); k, v [B,Hkv,C,Dh]; k_pos [B,C] (−1 = invalid slot).

    ``contiguous_offset``: pass the *static* chunk offset when the buffer
    prefix is known contiguous (slot i == position i — every chunk before
    prefill-phase compression first triggers). That dispatches the Pallas
    flash kernel through its existing ``q_offset`` path: invalid tail slots
    sit at arange positions beyond every real query and are causally
    masked, so the slotted call and the flash call agree. Without it (or
    with ``impl="ref"``) the XLA-native slotted oracle runs, which accepts
    traced offsets and arbitrary (compressed) key layouts.

    ``k_scale``/``v_scale`` [B,Hkv,C]: int8 block-scaled working buffer —
    dequantised in VMEM on the flash path, in the oracle otherwise.
    """
    impl = _resolve(impl)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    win = None
    if window is not None and contiguous_offset is not None:
        try:
            win = int(window)    # flash path needs a static window
        except (jax.errors.TracerIntegerConversionError,
                jax.errors.ConcretizationTypeError):
            # traced per-layer window (local/global layer scans): the
            # flash kernel can't take it — use the slotted oracle
            contiguous_offset = None
    if impl == "ref" or contiguous_offset is None:
        return ref_impl.chunk_attention_ref(
            q, k, v, k_pos, q_start, window=window, softcap=softcap,
            scale=scale, k_scale=k_scale, v_scale=v_scale)
    out, _ = flash_prefill_pallas(
        q, k, v, scale=scale, softcap=softcap, causal=True, window=win,
        q_offset=contiguous_offset, interpret=(impl == "interpret"),
        k_scale=k_scale, v_scale=v_scale)
    return out


def obs_colsums(q_win: jax.Array, k: jax.Array, *, win_start,
                window: int | None = None, softcap: float | None = None,
                scale: float | None = None,
                k_pos: jax.Array | None = None,
                k_scale: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Observation-window exact column sums + probs (prefill RASR init and
    layerwise Hoyer estimate). Small (W ≤ 64 rows), always XLA-native.
    ``k_pos`` [B, S] masks a slotted (compressed-prefill) key layout;
    ``k_scale`` [B, Hkv, S] dequantises an int8 one."""
    scale = scale if scale is not None else q_win.shape[-1] ** -0.5
    return ref_impl.obs_colsums_ref(
        q_win, k, win_start=win_start, window=window, softcap=softcap,
        scale=scale, k_pos=k_pos, k_scale=k_scale)
