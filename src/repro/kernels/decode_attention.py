"""Fused decode attention over a slotted pruned KV cache (Pallas TPU).

The paper reads attention probabilities back out of the attention op to
update RASR scores (Eq. 5). On TPU, re-materialising the prob matrix would
cost an extra HBM round-trip per step, so this kernel *fuses* the Eq. 2/Eq. 5
bookkeeping into flash-decode: alongside the attention output it emits the
per-key probability column-sums Σ_g probs[g, c] for each KV head.

Design (TPU-native, see DESIGN.md §2):
  grid = (B, H_kv, C // block_c) — the C axis is innermost and sequential,
  so online-softmax statistics live in VMEM scratch across C-blocks:
    m, l   [G, 1]    running row max / denominator (G = H_q/H_kv group)
    acc    [G, Dh]   output accumulator
    psum   [G, C]    unnormalised prob column accumulator, rescaled online
  K/V stream through VMEM in (block_c × Dh) tiles. GQA is native — the
  group dim G rides the MXU's row axis and keys are never repeated
  (Eq. 3's ``repeat`` is purely logical).

Masking (validity of pruned slots, causality, sliding window) is folded into
an additive bias [B, C] computed by the wrapper — one vector per row, not a
matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, out_ref, psum_ref,
            m_s, l_s, acc_s, ps_s, *, scale: float, softcap: float | None,
            block_c: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)
        ps_s[...] = jnp.zeros_like(ps_s)

    q = q_ref[0, 0].astype(jnp.float32)                   # [G, Dh]
    kb = k_ref[0, 0].astype(jnp.float32)                  # [BC, Dh]
    vb = v_ref[0, 0].astype(jnp.float32)                  # [BC, Dh]
    bias = bias_ref[0].astype(jnp.float32)                # [BC]

    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = s + bias[None, :]                                  # [G, BC]

    m_old = m_s[:, 0]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_old - m_new)                         # [G]
    p = jnp.exp(s - m_new[:, None])                        # [G, BC]

    l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    # online rescale of every previously-accumulated prob column, then store
    # this block's unnormalised probs into its slice.
    ps_s[...] = ps_s[...] * alpha[:, None]
    ps_s[:, pl.ds(c * block_c, block_c)] = (
        ps_s[:, pl.ds(c * block_c, block_c)] + p)
    m_s[:, 0] = m_new

    @pl.when(c == nc - 1)
    def _finalize():
        denom = jnp.maximum(l_s[:, 0], 1e-30)              # [G]
        out_ref[0, 0] = (acc_s[...] / denom[:, None]).astype(out_ref.dtype)
        psum_ref[0, 0] = jnp.sum(ps_s[...] / denom[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "block_c",
                                             "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            bias: jax.Array, *, scale: float,
                            softcap: float | None = None,
                            block_c: int = 512,
                            interpret: bool = False
                            ) -> tuple[jax.Array, jax.Array]:
    """q: [B, Hq, Dh]; k, v: [B, Hkv, C, Dh]; bias: [B, C] additive mask.

    Returns (out [B, Hq, Dh], probsum [B, C]). C is padded to block_c inside.
    """
    B, Hq, Dh = q.shape
    _, Hkv, C, _ = k.shape
    G = Hq // Hkv
    assert G * Hkv == Hq, (Hq, Hkv)

    block_c = min(block_c, max(C, 8))
    pad = (-C) % block_c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG_INF)
    Cp = C + pad
    nc = Cp // block_c

    qg = q.reshape(B, Hkv, G, Dh)
    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               block_c=block_c)
    out, psum = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nc),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_c, Dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, block_c, Dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, block_c), lambda b, h, c: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Cp), lambda b, h, c: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, Cp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, Cp), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, bias)

    out = out.reshape(B, Hq, Dh)
    probsum = jnp.sum(psum, axis=1)[:, :C]                 # Σ over KV heads
    return out, probsum


def make_decode_bias(pos: jax.Array, cur_pos: jax.Array,
                     window: int | None = None) -> jax.Array:
    """Additive mask bias [B, C] from slot positions: invalid slots, future
    positions and (optionally) out-of-window positions get NEG_INF."""
    B = pos.shape[0]
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))[:, None]
    ok = (pos >= 0) & (pos <= cur)
    if window is not None:
        ok &= pos >= (cur - window + 1)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
