"""Fused decode attention over a slotted pruned KV cache (Pallas TPU).

The paper reads attention probabilities back out of the attention op to
update RASR scores (Eq. 5). On TPU, re-materialising the prob matrix would
cost an extra HBM round-trip per step, so this kernel *fuses* the Eq. 2/Eq. 5
bookkeeping into flash-decode: alongside the attention output it emits the
per-key probability column-sums Σ_g probs[g, c] for each KV head AND applies
the Eq. 5 EMA (score ← γ·score + probsum) in the kernel epilogue, so no
separate [B, C] read-modify-write pass over the score buffer exists.

Design (TPU-native, see DESIGN.md §2):
  grid = (B, H_kv, C // block_c) — the C axis is innermost and sequential,
  so online-softmax statistics live in VMEM scratch across C-blocks:
    m, l   [G, 1]    running row max / denominator (G = H_q/H_kv group)
    acc    [G, Dh]   output accumulator
    psum   [G, C]    unnormalised prob column accumulator, rescaled online
  K/V stream through VMEM in (block_c × Dh) tiles. GQA is native — the
  group dim G rides the MXU's row axis and keys are never repeated
  (Eq. 3's ``repeat`` is purely logical).

Occupancy-adaptive early exit (DESIGN.md §2.3): the per-row live length is
scalar-prefetched (``pltpu.PrefetchScalarGridSpec``), every C-block past the
last live block is skipped with ``pl.when`` and its K/V index map is clamped
onto the last live block so dead blocks neither DMA fresh tiles nor touch the
accumulators. Because pruning packs valid slots at the front of the cache
(the ``KVCache`` invariant), attention FLOPs and HBM traffic track the
pruning sawtooth instead of the static capacity ``C``.

Masking (validity of pruned slots, causality, sliding window) is derived
*inside* the kernel from the slot-position row — no [B, C] f32 bias array is
materialised in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
GLOBAL_WINDOW = 1 << 30  # sentinel: effectively unwindowed


def _kernel(lens_ref, cur_ref, win_ref,                    # scalar prefetch
            *refs,                                         # ins/outs/scratch
            scale: float, softcap: float | None, gamma: float, block_c: int,
            quantized: bool):
    # Positional layout (PrefetchScalarGridSpec hands refs flat): the int8
    # path interleaves a per-(token, head) scales block after each payload —
    # dequant happens here in VMEM, before the QK/PV matmuls, so the HBM DMA
    # per C-block is the int8 tile + one f32 scale row instead of a bf16
    # tile (≈ 53% of the bytes at Dh = 64).
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref, pos_ref, score_ref,
         out_ref, psum_ref, nscore_ref, blocks_ref,
         m_s, l_s, acc_s, ps_s, cnt_s) = refs
    else:
        (q_ref, k_ref, v_ref, pos_ref, score_ref,
         out_ref, psum_ref, nscore_ref, blocks_ref,
         m_s, l_s, acc_s, ps_s, cnt_s) = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    h = pl.program_id(1)
    c = pl.program_id(2)
    nh = pl.num_programs(1)
    # Number of live C-blocks for this row; ≥ 1 so outputs are always written.
    nb = jnp.maximum(pl.cdiv(lens_ref[b], block_c), 1)

    @pl.when(c < nb)
    def _compute():
        @pl.when(c == 0)
        def _init():
            m_s[...] = jnp.full_like(m_s, NEG_INF)
            l_s[...] = jnp.zeros_like(l_s)
            acc_s[...] = jnp.zeros_like(acc_s)
            ps_s[...] = jnp.zeros_like(ps_s)
            cnt_s[0] = 0

        q = q_ref[0, 0].astype(jnp.float32)                # [G, Dh]
        kb = k_ref[0, 0].astype(jnp.float32)               # [BC, Dh]
        vb = v_ref[0, 0].astype(jnp.float32)               # [BC, Dh]
        if quantized:
            kb = kb * ks_ref[0, 0][:, None]                # VMEM dequant
            vb = vb * vs_ref[0, 0][:, None]
        # In-kernel mask from slot positions: invalid (-1) slots, future
        # positions, and out-of-window positions are dead.
        pos_blk = pos_ref[0, pl.ds(c * block_c, block_c)]  # [BC] int32
        cur = cur_ref[b]
        ok = (pos_blk >= 0) & (pos_blk <= cur) & (pos_blk > cur - win_ref[0])

        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(ok[None, :], s, NEG_INF)             # [G, BC]

        m_old = m_s[:, 0]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_old - m_new)                     # [G]
        p = jnp.exp(s - m_new[:, None])                    # [G, BC]

        l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # online rescale of every previously-accumulated prob column, then
        # store this block's unnormalised probs into its slice.
        ps_s[...] = ps_s[...] * alpha[:, None]
        ps_s[:, pl.ds(c * block_c, block_c)] = (
            ps_s[:, pl.ds(c * block_c, block_c)] + p)
        m_s[:, 0] = m_new
        cnt_s[0] += 1

        @pl.when(c == nb - 1)
        def _finalize():
            denom = jnp.maximum(l_s[:, 0], 1e-30)          # [G]
            out_ref[0, 0] = (acc_s[...] / denom[:, None]).astype(out_ref.dtype)
            row = jnp.sum(ps_s[...] / denom[:, None], axis=0)  # [Cp]
            blocks_ref[0, 0] = cnt_s[0]
            # Σ over KV heads accumulates in the revisited output block (the
            # h axis maps every program onto the same [1, Cp] row).

            @pl.when(h == 0)
            def _first_head():
                psum_ref[0] = row

            @pl.when(h > 0)
            def _other_heads():
                psum_ref[0] = psum_ref[0] + row

            @pl.when(h == nh - 1)
            def _rasr_epilogue():
                # Eq. 5 EMA fused in: score ← γ·score + Σ_h probsum, zeroed
                # on invalid slots (dead blocks were never touched, so their
                # psum columns are exactly 0 and scores stay 0).
                valid = pos_ref[0] >= 0
                nscore_ref[0] = jnp.where(
                    valid, gamma * score_ref[0] + psum_ref[0], 0.0)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "gamma",
                                             "block_c", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            pos: jax.Array, score: jax.Array,
                            lens: jax.Array, cur_pos: jax.Array,
                            window: jax.Array, *, scale: float,
                            softcap: float | None = None,
                            gamma: float = 0.0,
                            block_c: int = 512,
                            interpret: bool = False,
                            k_scale: jax.Array | None = None,
                            v_scale: jax.Array | None = None
                            ) -> tuple[jax.Array, jax.Array, jax.Array,
                                       jax.Array]:
    """Fused decode attention + RASR over a slotted cache.

    q: [B, Hq, Dh]; k, v: [B, Hkv, C, Dh]; pos: [B, C] int32 (-1 = invalid);
    score: [B, C] f32 RASR scores; lens: [B] int32 live lengths (valid slots
    are packed in [0, lens)); cur_pos: scalar or [B] query position; window:
    scalar int32 sliding window (``GLOBAL_WINDOW`` = unwindowed).

    ``k_scale``/``v_scale`` [B, Hkv, C]: when given, k/v hold int8
    block-scaled payloads and each C-block is dequantised in VMEM right
    after its (half-sized) DMA — the int8 hot path of DESIGN.md
    §Quantization. The scales stream through the same clamped index map as
    their payload, so the early-exit DMA skip covers them too.

    Returns (out [B, Hq, Dh], probsum [B, C], new_score [B, C],
    blocks [B, Hkv] — the number of C-blocks each program actually computed,
    the occupancy-proportionality counter used by tests/benchmarks).
    """
    B, Hq, Dh = q.shape
    _, Hkv, C, _ = k.shape
    G = Hq // Hkv
    assert G * Hkv == Hq, (Hq, Hkv)
    quantized = k_scale is not None

    block_c = min(block_c, max(C, 8))
    pad = (-C) % block_c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
        score = jnp.pad(score, ((0, 0), (0, pad)))
        if quantized:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad)))
    Cp = C + pad
    nc = Cp // block_c

    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (B,))
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))
    win = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (1,))
    qg = q.reshape(B, Hkv, G, Dh)
    score = score.astype(jnp.float32)

    def kv_map(b, h, c, lens_ref, cur_ref, win_ref):
        # Clamp dead blocks onto the last live block: the index map returns
        # the same block as the previous grid step, so the pipeline skips
        # the DMA entirely.
        nb = jnp.maximum(pl.cdiv(lens_ref[b], block_c), 1)
        return (b, h, jnp.minimum(c, nb - 1), 0)

    def scale_map(b, h, c, lens_ref, cur_ref, win_ref):
        nb = jnp.maximum(pl.cdiv(lens_ref[b], block_c), 1)
        return (b, h, jnp.minimum(c, nb - 1))

    def row_map(b, h, c, *_):
        return (b, 0)

    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               gamma=gamma, block_c=block_c,
                               quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, G, Dh), lambda b, h, c, *_: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, block_c, Dh), kv_map),
    ]
    inputs = [qg, k]
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, block_c), scale_map))
        inputs.append(k_scale)
    in_specs.append(pl.BlockSpec((1, 1, block_c, Dh), kv_map))
    inputs.append(v)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, block_c), scale_map))
        inputs.append(v_scale)
    in_specs += [pl.BlockSpec((1, Cp), row_map),
                 pl.BlockSpec((1, Cp), row_map)]
    inputs += [pos, score]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, nc),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, c, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, Cp), row_map),
            pl.BlockSpec((1, Cp), row_map),
            pl.BlockSpec((1, 1), lambda b, h, c, *_: (b, h)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, Cp), jnp.float32),
            pltpu.SMEM((1,), jnp.int32),
        ],
    )
    out, psum, nscore, blocks = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Cp), jnp.float32),
            jax.ShapeDtypeStruct((B, Cp), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv), jnp.int32),
        ],
        interpret=interpret,
    )(lens, cur, win, *inputs)

    out = out.reshape(B, Hq, Dh)
    return out, psum[:, :C], nscore[:, :C], blocks


def live_lengths(pos: jax.Array) -> jax.Array:
    """[B] int32 — index one past the last valid slot of each row.

    Equals ``KVCache.length`` under the packed-front invariant but is also
    correct (as an early-exit bound) for arbitrary slot layouts.
    """
    C = pos.shape[-1]
    occ = jnp.where(pos >= 0, jnp.arange(C, dtype=jnp.int32) + 1, 0)
    return jnp.max(occ, axis=-1).astype(jnp.int32)
