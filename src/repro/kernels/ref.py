"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references (tests assert allclose against them) and
the XLA-native implementation used on CPU and inside the dry-run lowering
(`impl="ref"` — XLA's own fusion stands in for the hand-written TPU kernel;
FLOP/byte counts for the roofline are equivalent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30  # finite sentinel: keeps fully-masked rows NaN-free


def _softcap(s: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def _deq(x: jax.Array, scale: jax.Array | None) -> jax.Array:
    """Dequantise an int8 block-scaled K/V tensor [..., C, Dh] with
    per-(token, kv-head) scales [..., C] — the oracle-side spelling of the
    in-kernel VMEM dequant (f32 multiply before the QK/PV matmuls). No-op
    (plain f32 cast) when ``scale`` is None (the dense path)."""
    if scale is None:
        return x.astype(jnp.float32)
    return x.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos: jax.Array, cur_pos: jax.Array, *,
                         window: int | None = None,
                         softcap: float | None = None,
                         scale: float | None = None,
                         k_scale: jax.Array | None = None,
                         v_scale: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Single-token decode attention over a slotted (possibly pruned) cache,
    emitting the RASR per-key probability column-sums.

    q:   [B, Hq, Dh]      (one new token per row)
    k,v: [B, Hkv, C, Dh]  slotted cache (int8 when scales are given)
    pos: [B, C]           original positions; -1 marks invalid slots
    cur_pos: scalar or [B] — the query token's position
    k_scale, v_scale: [B, Hkv, C] optional int8 dequant scales

    Returns (out [B, Hq, Dh], probsum [B, C] = Σ_h probs — Eq. 2 head-invariant
    scoring; GQA handled by group reshape, no repeated-key materialisation).
    """
    B, Hq, Dh = q.shape
    _, Hkv, C, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,))

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Dh)
    kf = _deq(k, k_scale)
    s = jnp.einsum("bhgd,bhcd->bhgc", qf, kf) * scale      # [B,Hkv,G,C]
    s = _softcap(s, softcap)

    valid = pos >= 0
    mask = valid & (pos <= cur[:, None])
    if window is not None:
        mask &= pos >= (cur[:, None] - window + 1)
    s = jnp.where(mask[:, None, None, :], s, _NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / jnp.maximum(denom, 1e-30)                   # [B,Hkv,G,C]
    out = jnp.einsum("bhgc,bhcd->bhgd", probs, _deq(v, v_scale))
    probsum = jnp.sum(probs, axis=(1, 2))                   # [B, C]
    return out.reshape(B, Hq, Dh).astype(q.dtype), probsum


def decode_attention_fused_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                               pos: jax.Array, cur_pos: jax.Array,
                               score: jax.Array, *, gamma: float,
                               window: int | None = None,
                               softcap: float | None = None,
                               scale: float | None = None,
                               k_scale: jax.Array | None = None,
                               v_scale: jax.Array | None = None
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused decode-attention + RASR kernel: identical
    signature/semantics to ``decode_attention_pallas`` (sans the block
    counter). ``score``: [B, C] RASR scores before this step.

    Returns (out, probsum, new_score) with new_score the Eq. 5 EMA
    γ·score + probsum, zeroed on invalid slots — the exact arithmetic of the
    pre-fusion ``rasr.update_scores`` pass.

    Degenerate-case caveat (DESIGN.md §2.3): if *every* slot of a row is
    masked, this oracle distributes the NaN-free sentinel mass uniformly over
    all C slots while the early-exit kernel distributes it over the live
    prefix only. No decode step can reach that state (the just-appended token
    is always attendable), so equivalence tests exclude it.
    """
    out, probsum = decode_attention_ref(
        q, k, v, pos, cur_pos, window=window, softcap=softcap, scale=scale,
        k_scale=k_scale, v_scale=v_scale)
    valid = pos >= 0
    new_score = jnp.where(valid,
                          gamma * score.astype(jnp.float32) + probsum, 0.0)
    return out, probsum, new_score


def prefill_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool = True,
                          window: int | None = None,
                          softcap: float | None = None,
                          scale: float | None = None,
                          q_offset: int | jax.Array = 0
                          ) -> tuple[jax.Array, jax.Array]:
    """Full prefill attention (the flash-kernel oracle).

    q: [B, Hq, S, Dh]; k, v: [B, Hkv, T, Dh].
    Returns (out [B, Hq, S, Dh], lse [B, Hq, S]).
    ``q_offset`` positions q row i at absolute position q_offset + i (for
    chunked prefill); keys are at absolute positions 0..T-1.
    """
    B, Hq, S, Dh = q.shape
    _, Hkv, T, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, Dh)
    s = jnp.einsum("bhgsd,bhtd->bhgst", qf, k.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)

    q_pos = jnp.arange(S) + q_offset
    k_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] >= (q_pos[:, None] - window + 1)
    s = jnp.where(mask[None, None, None], s, _NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p / jnp.maximum(denom, 1e-30),
                     v.astype(jnp.float32))
    lse = (m[..., 0] + jnp.log(jnp.maximum(denom[..., 0], 1e-30)))
    return (out.reshape(B, Hq, S, Dh).astype(q.dtype),
            lse.reshape(B, Hq, S))


def prefill_attention_chunked_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                                  *, chunk: int = 1024,
                                  causal: bool = True,
                                  window: int | None = None,
                                  softcap: float | None = None,
                                  scale: float | None = None
                                  ) -> jax.Array:
    """Query-chunked prefill oracle: identical math to
    ``prefill_attention_ref`` but scores for only one q-chunk are ever
    resident (lax.map over chunks) — the HBM-residency shape of the Pallas
    flash kernel, expressible in pure jnp. Used by the dry-run when
    REPRO_PREFILL_CHUNKED is set (§Perf, prefill memory term)."""
    B, Hq, S, Dh = q.shape
    chunk = min(chunk, S)
    if S % chunk:
        pad = (-S) % chunk
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        S_p = S + pad
    else:
        S_p = S
    n = S_p // chunk
    qc = q.reshape(B, Hq, n, chunk, Dh)

    def one(i):
        out, _ = prefill_attention_ref(
            qc[:, :, i], k, v, causal=causal, window=window,
            softcap=softcap, scale=scale, q_offset=i * chunk)
        return out

    outs = jax.lax.map(one, jnp.arange(n))        # [n, B, Hq, chunk, Dh]
    out = jnp.moveaxis(outs, 0, 2).reshape(B, Hq, S_p, Dh)
    return out[:, :, :S]


def chunk_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        k_pos: jax.Array, q_start, *,
                        window=None,
                        softcap: float | None = None,
                        scale: float | None = None,
                        k_scale: jax.Array | None = None,
                        v_scale: jax.Array | None = None) -> jax.Array:
    """Chunk-of-queries attention over a *slotted* cache — the inner step of
    chunked prefill once prefill-phase compression has made the key layout
    non-contiguous.

    q:     [B, Hq, n, Dh]  — n consecutive prompt tokens at absolute
                             positions ``q_start .. q_start+n-1`` (``q_start``
                             may be traced).
    k, v:  [B, Hkv, C, Dh] — slotted working buffer.
    k_pos: [B, C]          — original key positions; -1 marks invalid slots.

    Masking: validity (k_pos ≥ 0), causality (k_pos ≤ q_pos) and the
    optional sliding ``window`` (a traced per-layer scalar is fine). On a
    contiguous buffer (slot i holds position i) this reproduces
    ``prefill_attention_ref(..., q_offset=q_start)`` bit-for-bit: the masked
    score tensors are identical and the extra invalid columns contribute
    exact zeros to the softmax sums.

    Returns out [B, Hq, n, Dh].
    """
    B, Hq, n, Dh = q.shape
    _, Hkv, C, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, n, Dh)
    s = jnp.einsum("bhgsd,bhcd->bhgsc", qf, _deq(k, k_scale)) * scale
    s = _softcap(s, softcap)

    q_pos = jnp.arange(n) + q_start                          # [n]
    mask = (k_pos[:, None, :] >= 0) \
        & (k_pos[:, None, :] <= q_pos[None, :, None])        # [B, n, C]
    if window is not None:
        mask &= k_pos[:, None, :] >= (q_pos[None, :, None] - window + 1)
    s = jnp.where(mask[:, None, None], s, _NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgsc,bhcd->bhgsd", p / jnp.maximum(denom, 1e-30),
                     _deq(v, v_scale))
    return out.reshape(B, Hq, n, Dh).astype(q.dtype)


def obs_colsums_ref(q_win: jax.Array, k: jax.Array, *,
                    win_start: int | jax.Array,
                    window: int | None = None,
                    softcap: float | None = None,
                    scale: float | None = None,
                    k_pos: jax.Array | None = None,
                    k_scale: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Exact attention-mass column sums over an observation window.

    q_win: [B, Hq, W, Dh] — the last W prefill queries (absolute positions
    win_start .. win_start+W-1); k: [B, Hkv, S, Dh].

    ``k_pos`` [B, S] gives explicit key positions for slotted buffers
    (chunked prefill after compression; -1 = invalid slot, fully masked).
    When omitted, keys are contiguous at positions 0..S-1.
    ``k_scale`` [B, Hkv, S]: int8 dequant scales for a quantized buffer.

    Returns (colsums [B, S] = Σ_h Σ_{q∈win} probs, probs [B, Hq, W, S]) —
    the probs feed the layerwise Hoyer sparsity estimator.
    """
    B, Hq, W, Dh = q_win.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5

    qf = q_win.astype(jnp.float32).reshape(B, Hkv, G, W, Dh)
    s = jnp.einsum("bhgwd,bhsd->bhgws", qf, _deq(k, k_scale)) * scale
    s = _softcap(s, softcap)

    q_pos = jnp.arange(W) + win_start
    if k_pos is None:
        kp = jnp.broadcast_to(jnp.arange(S), (B, S))
    else:
        kp = k_pos
    mask = (kp[:, None, :] >= 0) & (kp[:, None, :] <= q_pos[None, :, None])
    if window is not None:
        mask &= kp[:, None, :] >= (q_pos[None, :, None] - window + 1)
    s = jnp.where(mask[:, None, None], s, _NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)                      # [B,Hkv,G,W,S]
    colsums = jnp.sum(probs, axis=(1, 2, 3))                # [B, S]
    return colsums, probs.reshape(B, Hq, W, S)
