"""Flash prefill attention (Pallas TPU): causal/sliding-window GQA forward
with per-row LSE output.

grid = (B, H_kv, S//block_q, T//block_k); the key axis is innermost, so the
online-softmax state for one query tile lives in VMEM scratch:
    m, l  [G·BQ, 1]   running max / denominator
    acc   [G·BQ, Dh]  output accumulator
Causal/out-of-window key tiles are skipped with ``pl.when`` (no wasted MXU
work below the diagonal). The LSE output feeds the exact observation-window
column-sum pass (see kernels/ops.py) that initialises Lethe's RASR scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(*refs, scale: float, softcap: float | None, causal: bool,
            window: int | None, block_q: int, block_k: int, q_offset: int,
            quantized: bool):
    # int8 path: a per-(token, kv-head) scales block rides after each K/V
    # payload block and is applied in VMEM before the matmuls (same
    # in-kernel dequant as the decode kernel; DESIGN.md §Quantization).
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref, out_ref, lse_ref,
         m_s, l_s, acc_s) = refs
    else:
        q_ref, k_ref, v_ref, out_ref, lse_ref, m_s, l_s, acc_s = refs
        ks_ref = vs_ref = None
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_start = iq * block_q + q_offset
    k_start = ik * block_k

    # Tile-level skip: entirely above the causal diagonal or entirely left of
    # every query's window.
    needed = jnp.asarray(True)
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        # newest query in tile is q_start+block_q-1; oldest allowed key is
        # (q_start) - window + 1; skip tiles entirely older than that.
        needed = jnp.logical_and(
            needed, (k_start + block_k - 1) >= (q_start - window + 1))

    @pl.when(needed)
    def _compute():
        G, BQ, Dh = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
        q = q_ref[0, 0].astype(jnp.float32).reshape(G * BQ, Dh)
        kb = k_ref[0, 0].astype(jnp.float32)               # [BK, Dh]
        vb = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            kb = kb * ks_ref[0, 0][:, None]                # VMEM dequant
            vb = vb * vs_ref[0, 0][:, None]

        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (G, BQ), 1
                                                   ).reshape(G * BQ)
        k_pos = k_start + jax.lax.iota(jnp.int32, block_k)
        ok = jnp.ones((G * BQ, block_k), bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= k_pos[None, :] >= (q_pos[:, None] - window + 1)
        s = jnp.where(ok, s, NEG_INF)

        m_old = m_s[:, 0]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[:, 0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        G, BQ, Dh = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
        denom = jnp.maximum(l_s[:, 0], 1e-30)
        out_ref[0, 0] = (acc_s[...] / denom[:, None]).reshape(
            G, BQ, Dh).astype(out_ref.dtype)
        lse = m_s[:, 0] + jnp.log(denom)
        lse_ref[0, 0] = lse.reshape(G, BQ).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "causal", "window", "block_q", "block_k",
    "q_offset", "interpret"))
def flash_prefill_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         scale: float, softcap: float | None = None,
                         causal: bool = True, window: int | None = None,
                         block_q: int = 256, block_k: int = 512,
                         q_offset: int = 0, interpret: bool = False,
                         k_scale: jax.Array | None = None,
                         v_scale: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """q: [B, Hq, S, Dh]; k, v: [B, Hkv, T, Dh].
    ``k_scale``/``v_scale`` [B, Hkv, T]: int8 block-scaled K/V, dequantised
    per key tile in VMEM (the chunked-prefill contiguous fast path over a
    quantized working buffer).
    Returns (out [B, Hq, S, Dh], lse [B, Hq, S])."""
    B, Hq, S, Dh = q.shape
    _, Hkv, T, _ = k.shape
    G = Hq // Hkv
    assert G * Hkv == Hq
    quantized = k_scale is not None

    block_q = min(block_q, S)
    block_k = min(block_k, T)
    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded keys are masked out by the causal test (their positions
        # exceed every real query position when causal; for non-causal we
        # mask via window... safest: pad then rely on causal; non-causal
        # unpadded T is required.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        if quantized:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad_k)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad_k)))
    if pad_k and not causal:
        raise ValueError("non-causal prefill requires T % block_k == 0")
    Sp, Tp = S + pad_q, T + pad_k

    qg = q.reshape(B, Hkv, G, Sp, Dh)
    kernel = functools.partial(
        _kernel, scale=scale, softcap=softcap, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset,
        quantized=quantized)
    kv_spec = pl.BlockSpec((1, 1, block_k, Dh),
                           lambda b, h, iq, ik: (b, h, ik, 0))
    sc_spec = pl.BlockSpec((1, 1, block_k),
                           lambda b, h, iq, ik: (b, h, ik))
    in_specs = [pl.BlockSpec((1, 1, G, block_q, Dh),
                             lambda b, h, iq, ik: (b, h, 0, iq, 0)),
                kv_spec]
    inputs = [qg, k]
    if quantized:
        in_specs.append(sc_spec)
        inputs.append(k_scale)
    in_specs.append(kv_spec)
    inputs.append(v)
    if quantized:
        in_specs.append(sc_spec)
        inputs.append(v_scale)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, Hkv, Sp // block_q, Tp // block_k),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, block_q, Dh),
                         lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            pl.BlockSpec((1, 1, G, block_q),
                         lambda b, h, iq, ik: (b, h, 0, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Sp, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, Sp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)

    out = out.reshape(B, Hq, Sp, Dh)[:, :, :S]
    lse = lse.reshape(B, Hq, Sp)[:, :, :S]
    return out, lse
