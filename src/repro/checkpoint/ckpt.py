"""Minimal pytree checkpointing (npz + structure manifest) — no orbax in
this environment. Handles nested dict/list/tuple/NamedTuple pytrees of
jnp/np arrays plus scalar leaves."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves = _flatten_with_paths(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, (_, leaf) in enumerate(leaves)}
    manifest = {
        "keys": [k for k, _ in leaves],
        "step": step,
    }
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def restore(path: str, tree_like):
    """Restore into the structure of ``tree_like`` (shape donor)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    flat_like = _flatten_with_paths(tree_like)
    by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}
    leaves = []
    for key, leaf in flat_like:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype)
                      if hasattr(leaf, "dtype") else arr.item())
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(path: str) -> int | None:
    try:
        with open(path + ".json") as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
