"""Minimal pytree checkpointing (npz + structure manifest) — no orbax in
this environment. Handles nested dict/list/tuple/NamedTuple pytrees of
jnp/np arrays plus scalar leaves.

Also the *bit-exact* pack/unpack pair the durability layer and the prefix
store share (``pack_bitexact``/``unpack_bitexact``): numpy's npz format
preserves the raw bytes of extension dtypes (ml_dtypes bfloat16) but
degrades their dtype to an opaque void on load, so packing records every
leaf's dtype name and unpacking view-casts the loaded bytes back. The
round trip is the identity on bit patterns — which is what lets a slot
snapshot (``cache.extract_slots``) go to disk and come back through
``cache.insert_slots`` bitwise unchanged, the property the crash-recovery
checkpoints rest on."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves = _flatten_with_paths(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, (_, leaf) in enumerate(leaves)}
    manifest = {
        "keys": [k for k, _ in leaves],
        "step": step,
    }
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def restore(path: str, tree_like):
    """Restore into the structure of ``tree_like`` (shape donor)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    flat_like = _flatten_with_paths(tree_like)
    by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}
    leaves = []
    for key, leaf in flat_like:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype)
                      if hasattr(leaf, "dtype") else arr.item())
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# --------------------------------------------------------------------------
# Bit-exact pytree (de)serialization — shared by serving/durability.py
# (pool checkpoints) and serving/prefix_cache.py (store persistence).
# --------------------------------------------------------------------------

def _resolve_dtype(name: str) -> np.dtype:
    """Dtype by name, resolving ml_dtypes extension dtypes (bfloat16,
    float8_*, ...) that plain ``np.dtype(name)`` does not know."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def pack_bitexact(tree, prefix: str = "") -> tuple[dict, dict]:
    """Flatten a (numpy or jax) pytree into npz-storable arrays plus a
    JSON-safe meta block recording key order and true dtype names. ``None``
    leaves (e.g. the dense path's absent k_scale) are recorded in the meta
    and skipped. ``prefix`` namespaces the keys so several trees can share
    one npz."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays: dict[str, np.ndarray] = {}
    keys, dtypes = [], []
    for path, leaf in flat:
        key = prefix + "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        keys.append(key)
        dtypes.append(arr.dtype.name)
        arrays[key] = arr
    return arrays, {"keys": keys, "dtypes": dtypes, "prefix": prefix}


def unpack_bitexact(arrays, meta: dict, tree_like):
    """Rebuild the tree packed by ``pack_bitexact`` into the structure of
    ``tree_like`` (a shape/structure donor with the same leaf paths, e.g. a
    fresh ``extract_slots`` of an empty state). Loaded bytes are view-cast
    back to their recorded dtypes, so the round trip is bitwise."""
    by_key = dict(zip(meta["keys"], meta["dtypes"]))
    prefix = meta.get("prefix", "")
    flat_like = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    leaves = []
    for path, _ in flat_like:
        key = prefix + "/".join(_path_str(p) for p in path)
        if key not in by_key:
            raise KeyError(f"packed tree missing leaf {key!r}")
        arr = np.asarray(arrays[key])
        want = _resolve_dtype(by_key[key])
        if arr.dtype != want:
            arr = arr.view(want)
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_rows(path: str, tree) -> int:
    """One-tree convenience: ``<path>.npz`` + ``<path>.meta.json``.
    Returns payload bytes written (the npz size on disk)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, meta = pack_bitexact(tree)
    np.savez(path + ".npz", **arrays)
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)
    return os.path.getsize(path + ".npz")


def load_rows(path: str, tree_like):
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    with np.load(path + ".npz") as data:
        return unpack_bitexact(dict(data), meta, tree_like)


def latest_step(path: str) -> int | None:
    try:
        with open(path + ".json") as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
