"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × mesh), TPU v5e constants:

  compute    = HLO_FLOPs        / (chips × 197 TF/s bf16)
  memory     = HLO_bytes        / (chips × 819 GB/s HBM)
  collective = collective_bytes / (chips × 50 GB/s/link ICI)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. collective_bytes
is parsed out of the compiled HLO text: operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[4,128,1024]{2,1,0} all-gather(...)
# result may be tuple-shaped: (f32[..], u32[..]) all-reduce-start(...)
_OP_RE = re.compile(
    r"^%?[\w.\-]+\s*=\s*(.+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by kind.

    ``-done`` ops are skipped (their ``-start`` counterpart already counted);
    plain ops and ``-start`` ops count once each.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.match(stripped)
        if not m:
            continue
        if "-done(" in stripped:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        total = sum(_shape_bytes(d, s)
                    for d, s in _TUPLE_RE.findall(shapes_str))
        out[kind] += total
        out["total"] += total
    return out


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    flops_ratio: float  # MODEL_FLOPS / HLO_FLOPs ("useful compute" fraction)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "flops_ratio": self.flops_ratio,
        }


def roofline(flops: float, bytes_accessed: float, coll_bytes: float,
             n_chips: int, *, model_flops: float = 0.0) -> RooflineTerms:
    """``flops``/``bytes_accessed``/``coll_bytes`` are PER-DEVICE quantities
    (XLA's cost_analysis describes the per-partition SPMD program), so each
    term divides by a single chip's rate — algebraically identical to
    global_quantity / (chips × rate). ``model_flops`` is global and is
    normalised by n_chips for the useful-compute ratio."""
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = coll_bytes / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    per_dev_model = model_flops / max(n_chips, 1)
    ratio = (per_dev_model / flops) if flops > 0 else 0.0
    return RooflineTerms(
        compute_s=compute, memory_s=memory, collective_s=collective,
        dominant=dominant, model_flops=model_flops, flops_ratio=ratio)


def model_flops_for(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference forward), with
    N = active params (MoE counts routed experts only)."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens
