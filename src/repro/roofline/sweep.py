import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_UNROLL_LAYERS"] = "1"

"""Roofline measurement sweep with exact linear-in-L extrapolation.

XLA's cost_analysis counts a lax.scan body once, so the proof-of-lowering
sweep (launch/dryrun --all) undercounts per-layer costs. Fully unrolling the
production layer counts is exact but prohibitively slow to compile on one CPU
core. Instead this sweep lowers each case UNROLLED at two small layer counts
L1 < L2 (multiples of the arch's block pattern) and extrapolates to the full
L. Because all layers are structurally identical, every cost component is
either constant (embed/unembed/top-level) or exactly linear in L, so the
two-point fit  cost(L) = a + b·L  is exact, not approximate. (Time-axis
recurrences — RWKV6/RG-LRU scans over sequence — remain loops and are
documented analytically in EXPERIMENTS.md.)

Usage:
  PYTHONPATH=src python -m repro.roofline.sweep --out experiments/roofline_pod.jsonl
"""
import argparse
import json
import traceback

from repro.configs import SHAPES, get_arch, get_shape, list_archs
from repro.kernels import ops as kernel_ops
from repro.launch import specs
from repro.launch.dryrun import lower_case
from repro.launch.mesh import make_production_mesh

_EXTRAP_KEYS = ("flops", "bytes_accessed")


def _pattern_len(cfg) -> int:
    return max(1, len(cfg.block_pattern) or cfg.local_global_period or 1)


def _extrapolate(r1: dict, r2: dict, L1: int, L2: int, L: int) -> dict:
    out = dict(r2)
    for k in _EXTRAP_KEYS:
        b = (r2[k] - r1[k]) / (L2 - L1)
        a = r1[k] - b * L1
        out[k] = a + b * L
    coll = {}
    for k in r2["collective_bytes"]:
        b = (r2["collective_bytes"][k] - r1["collective_bytes"][k]) / (L2 - L1)
        a = r1["collective_bytes"][k] - b * L1
        coll[k] = a + b * L
    out["collective_bytes"] = coll
    mem = {}
    for k in r2["mem"]:
        b = (r2["mem"][k] - r1["mem"][k]) / (L2 - L1)
        a = r1["mem"][k] - b * L1
        mem[k] = a + b * L
    out["mem"] = mem
    out["layers_used"] = L
    out["extrapolated_from"] = [L1, L2]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--policy", default="lethe")
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()

    kernel_ops.set_default_impl("ref")
    mesh = make_production_mesh(multi_pod=args.multipod)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)

    for arch in archs:
        cfg = get_arch(arch)
        pat = _pattern_len(cfg)
        L1, L2 = pat, 2 * pat
        for shape_name in shapes:
            shape = get_shape(shape_name)
            case = specs.case_for(cfg, shape, args.policy)
            if case.skip_reason:
                rec = {"arch": arch, "shape": shape_name,
                       "policy": args.policy, "ok": False, "skipped": True,
                       "reason": case.skip_reason}
            else:
                try:
                    r1 = lower_case(case, mesh, layers_override=L1)
                    r2 = lower_case(case, mesh, layers_override=L2)
                    rec = _extrapolate(r1, r2, L1, L2, cfg.n_layers)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "policy": args.policy, "ok": False,
                           "skipped": False,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-1500:]}
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            status = ("OK" if rec.get("ok")
                      else ("SKIP" if rec.get("skipped") else "FAIL"))
            print(f"[{status}] {arch} × {shape_name} "
                  + (f"flops={rec.get('flops', 0):.3e}" if rec.get("ok")
                     else rec.get("reason", rec.get("error", ""))[:120]),
                  flush=True)


if __name__ == "__main__":
    main()
