"""Roofline report generator: reads dry-run JSONL records and emits the
EXPERIMENTS.md §Roofline table.

Usage:
  PYTHONPATH=src python -m repro.roofline.report \
      experiments/dryrun_pod.jsonl [--md]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_arch, get_shape
from repro.roofline import analysis


def load(path: str) -> list[dict]:
    return [json.loads(l) for l in open(path)]


def enrich(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    cfg = get_arch(rec["arch"])
    shape = get_shape(rec["shape"])
    mf = analysis.model_flops_for(cfg, shape, rec["kind"])
    terms = analysis.roofline(
        rec["flops"], rec["bytes_accessed"],
        rec["collective_bytes"]["total"], rec["n_chips"], model_flops=mf)
    out = dict(rec)
    out["roofline"] = terms.as_dict()
    return out


def _fmt_s(x: float) -> str:
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def _advice(rec: dict) -> str:
    dom = rec["roofline"]["dominant"]
    kind = rec["kind"]
    if dom == "memory":
        if kind == "decode":
            return ("cache-bandwidth bound: shrink live cache (lower Lethe "
                    "capacity) or quantize KV to int8")
        return "activation traffic: fuse/remat or larger per-chip tiles"
    if dom == "collective":
        return ("resharding traffic: align layer in/out shardings to kill "
                "all-gathers")
    if kind == "decode":
        return "compute-bound decode: batch is large enough to feed the MXU"
    return "compute-bound: near roofline, watch flops_ratio for remat waste"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()

    rows = []
    for p in args.paths:
        for rec in load(p):
            e = enrich(rec)
            if e:
                rows.append(e)
            elif rec.get("skipped"):
                rows.append(rec)

    if args.md:
        print("| arch | shape | mesh | policy | compute | memory | "
              "collective | dominant | MODEL_FLOPS/HLO | bottleneck note |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("skipped"):
            if args.md:
                print(f"| {r['arch']} | {r['shape']} | — | {r['policy']} | "
                      f"— | — | — | — | — | SKIP: {r['reason'][:60]} |")
            continue
        t = r["roofline"]
        if args.md:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['policy']} | {_fmt_s(t['compute_s'])} | "
                  f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
                  f"**{t['dominant']}** | {t['flops_ratio']:.2f} | "
                  f"{_advice(r)} |")
        else:
            print(f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
                  f"{r['policy']:7s} c={t['compute_s']:.2e} "
                  f"m={t['memory_s']:.2e} x={t['collective_s']:.2e} "
                  f"dom={t['dominant']:10s} ratio={t['flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
