"""Synthetic reasoning-style data pipeline.

Two deterministic, seedable sources:

* ``lm_stream`` — a Zipfian token stream with local n-gram structure
  (compressible enough that a small model's loss visibly decreases), used by
  training examples/tests.

* ``reasoning_task`` — a synthetic multi-step "chain-of-thought" task in the
  spirit of Math500: the prompt encodes a chain of modular-arithmetic steps,
  the model must track running state across many tokens, and *early* tokens
  (the operand table — an analogue of the problem statement / attention
  sinks) stay relevant while intermediate scratch tokens go stale. This is
  the workload family where Lethe's claims live, and it gives the accuracy
  benchmarks a measurable task signal.

Both yield fixed-shape jnp batches, stateless-by-seed (no external data —
everything is built in-framework per the assignment).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram: int = 3


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = r ** (-a)
    return p / p.sum()


def lm_stream(cfg: DataConfig) -> Iterator[dict]:
    """Infinite iterator of {"tokens": [B, S+1]} (inputs ++ next-token
    labels are produced by shifting)."""
    rng = np.random.default_rng(cfg.seed)
    base = _zipf_probs(cfg.vocab_size, cfg.zipf_a)
    # fixed random bigram mixing table for structure
    shift = rng.integers(1, cfg.vocab_size, size=cfg.vocab_size)
    while True:
        toks = np.empty((cfg.batch_size, cfg.seq_len + 1), np.int32)
        cur = rng.choice(cfg.vocab_size, size=cfg.batch_size, p=base)
        for t in range(cfg.seq_len + 1):
            # 60%: deterministic successor (learnable), 40%: zipf noise
            det = (cur + shift[cur]) % cfg.vocab_size
            noise = rng.choice(cfg.vocab_size, size=cfg.batch_size, p=base)
            take_det = rng.random(cfg.batch_size) < 0.6
            cur = np.where(take_det, det, noise).astype(np.int32)
            toks[:, t] = cur
        yield {"tokens": jnp.asarray(toks)}


# --------------------------------------------------------------------------
# Synthetic chain-of-thought reasoning task
# --------------------------------------------------------------------------

# token layout: [0, R) = values, [R, R+4) = control tokens
_CTRL_START, _CTRL_STEP, _CTRL_ANS, _CTRL_PAD = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True)
class ReasoningConfig:
    n_values: int = 64           # modulus / value vocabulary
    n_steps: int = 24            # chain length (drives sequence length)
    batch_size: int = 8
    seed: int = 0

    @property
    def vocab_size(self) -> int:
        return self.n_values + 4

    @property
    def seq_len(self) -> int:
        # start v0 [table] (step op arg res)*n ans answer
        return 2 + self.n_steps * 4 + 2

    def ctrl(self, c: int) -> int:
        return self.n_values + c


def reasoning_batch(cfg: ReasoningConfig, step: int) -> dict:
    """One batch of chained modular arithmetic.

    Sequence: START v0  (STEP op arg res)*  ANS answer
    where res_{i} = (res_{i-1} + arg_i) % M for op 0 (add) and
          res_{i} = (res_{i-1} * arg_i) % M for op 1 (mul, arg odd),
    and answer = (res_n + v0) % M — the final answer needs BOTH the end of
    the chain (recency) and the initial value v0 from the sink region, so a
    policy that drops early tokens cannot answer. All `res` tokens are also
    supervised (stepwise CoT supervision).
    """
    rng = np.random.default_rng(cfg.seed + 7919 * step)
    M = cfg.n_values
    B, n = cfg.batch_size, cfg.n_steps
    v0 = rng.integers(0, M, size=B)
    ops = rng.integers(0, 2, size=(B, n))
    args = rng.integers(1, M, size=(B, n))
    args = np.where(ops == 1, args | 1, args)  # odd multipliers

    toks = np.full((B, cfg.seq_len), cfg.ctrl(_CTRL_PAD), np.int32)
    weights = np.zeros((B, cfg.seq_len), np.float32)
    toks[:, 0] = cfg.ctrl(_CTRL_START)
    toks[:, 1] = v0
    res = v0.copy()
    p = 2
    for i in range(n):
        toks[:, p] = cfg.ctrl(_CTRL_STEP)
        toks[:, p + 1] = ops[:, i]            # op encoded as value token 0/1
        toks[:, p + 2] = args[:, i]
        res = np.where(ops[:, i] == 0, (res + args[:, i]) % M,
                       (res * args[:, i]) % M)
        toks[:, p + 3] = res
        weights[:, p + 3] = 1.0               # supervise each CoT result
        p += 4
    answer = (res + v0) % M
    toks[:, p] = cfg.ctrl(_CTRL_ANS)
    toks[:, p + 1] = answer
    weights[:, p + 1] = 4.0                   # final answer weighted higher
    return {"tokens": jnp.asarray(toks), "loss_weights": jnp.asarray(weights),
            "answers": jnp.asarray(answer[:, None]),
            "answer_positions": np.array([p + 1]),
            "prefill_len": 2,
            # back-compat aliases
            "answer": jnp.asarray(answer), "answer_pos": p + 1}


def reasoning_stream(cfg: ReasoningConfig) -> Iterator[dict]:
    step = 0
    while True:
        yield reasoning_batch(cfg, step)
        step += 1


# --------------------------------------------------------------------------
# Long-range recall task (the anti-StreamingLLM workload)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecallConfig:
    """Key-value recall across a long CoT filler: k/v pairs appear *early*,
    then a long scratch chain, then a query for one early key. Recency-only
    policies (StreamingLLM) lose the pairs; attention-aware retention (H2O /
    Lethe) must keep them — the workload family behind Table 1's MMLU
    long-range-context subjects."""
    n_values: int = 64
    n_pairs: int = 8
    filler_steps: int = 24
    n_queries: int = 4
    batch_size: int = 8
    seed: int = 0

    @property
    def vocab_size(self) -> int:
        return self.n_values + 4

    @property
    def seq_len(self) -> int:
        # START (k v)*p  (STEP op arg res)*f  (ANS key answer)*q
        return 1 + 2 * self.n_pairs + 4 * self.filler_steps \
            + 3 * self.n_queries

    def ctrl(self, c: int) -> int:
        return self.n_values + c


def recall_batch(cfg: RecallConfig, step: int) -> dict:
    rng = np.random.default_rng(cfg.seed + 104729 * step)
    M, B, P, F = cfg.n_values, cfg.batch_size, cfg.n_pairs, cfg.filler_steps
    keys = np.stack([rng.choice(M, size=P, replace=False) for _ in range(B)])
    vals = rng.integers(0, M, size=(B, P))
    toks = np.full((B, cfg.seq_len), cfg.ctrl(_CTRL_PAD), np.int32)
    weights = np.zeros((B, cfg.seq_len), np.float32)
    toks[:, 0] = cfg.ctrl(_CTRL_START)
    p = 1
    for i in range(P):
        toks[:, p] = keys[:, i]
        toks[:, p + 1] = vals[:, i]
        p += 2
    # filler chain (same modular-arithmetic grammar as the reasoning task)
    res = rng.integers(0, M, size=B)
    for i in range(F):
        ops = rng.integers(0, 2, size=B)
        args = rng.integers(1, M, size=B)
        args = np.where(ops == 1, args | 1, args)
        toks[:, p] = cfg.ctrl(_CTRL_STEP)
        toks[:, p + 1] = ops
        toks[:, p + 2] = args
        res = np.where(ops == 0, (res + args) % M, (res * args) % M)
        toks[:, p + 3] = res
        weights[:, p + 3] = 0.25
        p += 4
    answers, answer_positions = [], []
    for q in range(cfg.n_queries):
        qi = rng.integers(0, P, size=B)
        q_keys = keys[np.arange(B), qi]
        q_vals = vals[np.arange(B), qi]
        toks[:, p] = cfg.ctrl(_CTRL_ANS)
        toks[:, p + 1] = q_keys
        toks[:, p + 2] = q_vals
        weights[:, p + 2] = 4.0
        answers.append(q_vals)
        answer_positions.append(p + 2)
        p += 3
    answers = np.stack(answers, axis=1)        # [B, n_queries]
    return {"tokens": jnp.asarray(toks), "loss_weights": jnp.asarray(weights),
            "answers": jnp.asarray(answers),
            "answer_positions": np.array(answer_positions),
            "prefill_len": 1 + 2 * P,
            "answer": jnp.asarray(answers[:, -1]),
            "answer_pos": answer_positions[-1]}
