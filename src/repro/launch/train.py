"""Training launcher.

On real hardware this drives the pjit'd train_step over the production mesh;
on CPU it runs reduced configs end-to-end. The dry-run path (launch/dryrun)
proves the full-scale mesh lowering; this driver proves the loop.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-20b --reduced \
      --steps 200 --seq-len 64 --batch 8 [--ckpt experiments/run1]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.data import pipeline
from repro.launch import steps
from repro.models.api import build_model
from repro.optim import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke-scale variant (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--vocab", type=int, default=0,
                    help="override vocab (smaller = faster CPU training)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.vocab)
    model = build_model(cfg)
    init_kw = ({"max_positions": args.seq_len + 8}
               if cfg.is_encoder_decoder else {})
    params = model.init(jax.random.PRNGKey(0), **init_kw)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.2f}M")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                                total_steps=args.steps)
    offset = 0
    extras = {}
    if cfg.family == "vlm":
        offset = 8
        extras["img_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(5), (args.batch, 8, cfg.d_model))
    if cfg.family == "audio":
        extras["enc_frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(5), (args.batch, 16, cfg.d_model))
    train_step = jax.jit(steps.make_train_step(model, opt_cfg,
                                               label_offset=offset))
    opt_state = adamw.init(params)
    data = pipeline.lm_stream(pipeline.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch))

    t0 = time.time()
    for i, batch in zip(range(args.steps), data):
        batch.update(extras)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            tput = args.batch * args.seq_len * (i + 1) / (time.time() - t0)
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"tok/s={tput:.0f}")
    if args.ckpt:
        ckpt.save(args.ckpt, params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
