"""Sharding rules: map every parameter / state / batch leaf to a
PartitionSpec on the production mesh.

Baseline scheme (Megatron-style tensor parallel on ``model``, batch data
parallel on ``(pod, data)``) with *divisibility-aware fallbacks* — jit input
shardings must divide exactly, so each rule carries a priority chain of
candidate axes and the first divisible one wins:

  * attention q/k/v projections — output (flattened head) axis on ``model``
  * attention output proj       — input axis on ``model``
  * MLP up/gate | down          — d_ff out | in on ``model``
  * MoE experts [E, D, F]       — expert axis on ``model`` when E divides
    (expert parallel: arctic 128e), else F (tensor parallel inside experts:
    mixtral 8e on a 16-way axis)
  * embed [V, D]                — vocab on ``model``, falling back to D
    (whisper's 51866 vocab is not 16-divisible)
  * KV cache [L,B,Hkv,C,Dh]     — batch on ``data``; on ``model``: KV heads
    when divisible (gemma2 kv=16), else capacity C (key-parallel
    flash-decode — GQA/MQA archs), else head dim
  * long_500k (B=1)             — capacity sharded over every mesh axis
    (sequence-parallel decode)
  * recurrent states            — batch on ``data``, width/heads on ``model``

Perf iterations on top of this baseline are logged in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import os
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.cache import KVCache


def _kv_priority() -> tuple[int, ...]:
    """Model-axis placement priority for the KV cache [Hkv, C, Dh] dims.

    Baseline "heads,cap,dh": prefer KV heads, fall back to capacity.
    §Perf finding (command-r decode_32k): capacity sharding makes every
    append/compact/argsort a cross-shard op (~10.9 GB/step of all-gather);
    "heads,dh,cap" keeps the C axis local — slot bookkeeping is free and
    attention pays only small partial-softmax all-reduces.
    """
    order = os.environ.get("REPRO_KV_SHARD_PRIORITY", "heads,cap,dh")
    idx = {"heads": 0, "cap": 1, "dh": 2}
    out = []
    for tok in order.split(","):
        tok = tok.strip()
        if tok not in idx:
            raise ValueError(
                f"REPRO_KV_SHARD_PRIORITY: invalid token {tok!r} in "
                f"{order!r}; valid tokens are 'heads', 'cap', 'dh' "
                "(comma-separated, e.g. 'heads,dh,cap')")
        out.append(idx[tok])
    return tuple(out)


def _model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _data_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _data_axes(mesh)]))


def _pick_axis(shape: Sequence[int], priority: Sequence[int],
               m: int) -> Optional[int]:
    """First axis in ``priority`` whose length divides m-way sharding."""
    for ax in priority:
        ax = ax % len(shape) if shape else 0
        if len(shape) > ax and shape[ax] % m == 0 and shape[ax] >= m:
            return ax
    return None


def _spec(ndim: int, axis: Optional[int], name) -> P:
    spec = [None] * ndim
    if axis is not None:
        spec[axis] = name
    return P(*spec)


# -- parameter rules: leaf name -> axis priority (negative = from the end) --
_PARAM_PRIORITY = {
    "unembed": (-1, -2),
    "wq": (-1,), "wk": (-1,), "wv": (-1,), "wo": (-2, -1),
    "bq": (-1,), "bk": (-1,), "bv": (-1,),
    "w_up": (-1,), "w_gate": (-1,), "w_down": (-2, -1),
    # rwkv6
    "wr": (-1,), "wg": (-1,),
    "cm_k": (-1,), "cm_v": (-2, -1), "cm_r": (-1,),
    # rglru
    "w_y": (-1,), "w_out": (-2, -1), "wa": (-1,), "wx": (-1,),
    "conv_b": (-1,), "ba": (-1,), "bx": (-1,), "lam": (-1,),
}
_MOE_TENSORS = {"w_up", "w_gate", "w_down"}
_REPLICATED = {"router", "pos_embed", "ddl_a", "ddl_b", "wd1", "wd2",
               "conv_w", "mu", "mu_x", "mu_ck", "mu_cr", "u", "w0",
               "gn_scale", "gn_bias"}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _path_has(path, name: str) -> bool:
    return any(getattr(p, "key", None) == name for p in path)


def _spec_for_param(path, leaf, m: int) -> P:
    name = _leaf_name(path)
    nd = leaf.ndim
    if nd == 0 or name in _REPLICATED:
        return P()
    if name == "embed":
        ax = _pick_axis(leaf.shape, (0, 1), m)
        return _spec(nd, ax, "model")
    if _path_has(path, "moe") and name in _MOE_TENSORS and nd >= 3:
        ax = _pick_axis(leaf.shape, (nd - 3, nd - 1, nd - 2), m)
        return _spec(nd, ax, "model")
    pri = _PARAM_PRIORITY.get(name)
    if pri is None:
        return P()
    ax = _pick_axis(leaf.shape, [p % nd for p in pri if -nd <= p < nd], m)
    return _spec(nd, ax, "model")


def param_specs(params: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    m = _model_size(mesh)
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_spec_for_param(path, leaf, m) for path, leaf in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(p_spec: Any) -> Any:
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), mu=p_spec,
                      nu=jax.tree.map(lambda s: s, p_spec,
                                      is_leaf=lambda x: isinstance(x, P)))


# --------------------------------------------------------------------------
# Decode-state shardings
# --------------------------------------------------------------------------

def _cache_specs(cache: KVCache, mesh: Mesh, batch_size: int,
                 serving: bool = False) -> KVCache:
    m = _model_size(mesh)
    daxes = _data_axes(mesh)
    dsz = _data_size(mesh)
    L, B, Hkv, C, Dh = cache.k.shape

    if serving:
        # Live serving layout: the capacity axis C must stay shard-local —
        # every slot op (append_token's one-hot select, prune_layer /
        # compress_prefill_layer compaction gathers, tree_update_slots /
        # reset_slot masked selects) is elementwise or a local gather over
        # C, so a C-local layout makes the whole slot lifecycle
        # collective-free (§Perf: capacity sharding turns each
        # append/compact/argsort into ~GBs of all-gather per step). The
        # model axis therefore follows the priority chain with 'cap'
        # removed; an indivisible batch replicates over data instead of
        # falling to the sequence-parallel branch.
        data_ok = batch_size >= dsz and batch_size % dsz == 0
        b_ax = (daxes if len(daxes) > 1 else daxes[0]) if data_ok else None
        pri = tuple(ax for ax in _kv_priority() if ax != 1)
        target = _pick_axis((Hkv, C, Dh), pri, m)
        kv = {
            0: P(None, b_ax, "model", None, None),
            2: P(None, b_ax, None, None, "model"),
            None: P(None, b_ax, None, None, None),
        }[target]
        vec = P(None, b_ax, None)
        ln = P(None, b_ax)
        sc = P(*tuple(kv)[:4]) if cache.quantized else None
        return KVCache(k=kv, v=kv, pos=vec, score=vec, length=ln,
                       budget=ln, evict_at=ln, sparsity=ln,
                       k_scale=sc, v_scale=sc)

    if batch_size >= dsz and batch_size % dsz == 0:
        b_ax = daxes if len(daxes) > 1 else daxes[0]
        # model axis placement: priority chain over (Hkv, C, Dh)
        target = _pick_axis((Hkv, C, Dh), _kv_priority(), m)
        model_on = {0: "heads", 1: "cap", 2: "dh"}.get(target, None)
        kv = {
            "heads": P(None, b_ax, "model", None, None),
            "cap": P(None, b_ax, None, "model", None),
            "dh": P(None, b_ax, None, None, "model"),
            None: P(None, b_ax, None, None, None),
        }[model_on]
        vec = (P(None, b_ax, "model") if model_on == "cap"
               else P(None, b_ax, None))
        ln = P(None, b_ax)
    else:
        # sequence-parallel decode (long_500k, B=1): C over every axis
        all_axes = tuple(mesh.axis_names)
        total = int(np.prod([mesh.shape[a] for a in all_axes]))
        if C % total == 0:
            kv = P(None, None, None, all_axes, None)
            vec = P(None, None, all_axes)
        elif C % m == 0:
            kv = P(None, None, None, "model", None)
            vec = P(None, None, "model")
        else:
            kv = P(None, None, None, None, None)
            vec = P(None, None, None)
        ln = P(None, None)
    # budget/evict_at/sparsity are per-row [L, B] (continuous batching keeps
    # per-request pruning state) — shard them like ``length``.
    # int8 dequant scales are [L, B, Hkv, C] — the K/V spec minus its Dh
    # axis, so scales co-shard with their payload blocks.
    sc = P(*tuple(kv)[:4]) if cache.quantized else None
    return KVCache(k=kv, v=kv, pos=vec, score=vec, length=ln,
                   budget=ln, evict_at=ln, sparsity=ln,
                   k_scale=sc, v_scale=sc)


def state_specs(state: Any, cfg: ArchConfig, mesh: Mesh,
                batch_size: int, serving: bool = False) -> Any:
    m = _model_size(mesh)
    daxes = _data_axes(mesh)
    dsz = _data_size(mesh)
    data_ok = batch_size >= dsz and batch_size % dsz == 0
    b_ax = (daxes if len(daxes) > 1 else daxes[0]) if data_ok else None

    def leaf_spec(path, leaf):
        nd = leaf.ndim
        name = _leaf_name(path)
        if name in ("cross_k", "cross_v"):        # [L,B,H,S,Dh]
            ax = _pick_axis(leaf.shape[2:], (0, 1, 2), m)
            spec = [None, b_ax, None, None, None]
            if ax is not None:
                spec[2 + ax] = "model"
            return P(*spec)
        if name == "wkv":                          # [L,B,H,N,N]
            ax = _pick_axis(leaf.shape[2:], (0, 1, 2), m)
            spec = [None, b_ax, None, None, None]
            if ax is not None:
                spec[2 + ax] = "model"
            return P(*spec)
        if name == "h":                            # [L,B,W]
            ax = _pick_axis(leaf.shape[2:], (0,), m)
            return P(None, b_ax, "model" if ax is not None else None)
        if name == "conv":                         # [L,B,cw-1,W]
            ax = _pick_axis(leaf.shape[3:], (0,), m)
            return P(None, b_ax, None, "model" if ax is not None else None)
        if name in ("x_tm", "x_cm"):               # [L,B,D]
            return P(None, b_ax, None)
        if nd >= 2:
            return P(*([None, b_ax] + [None] * (nd - 2)))
        return P()

    def spec_one(sub):
        if isinstance(sub, KVCache):
            return _cache_specs(sub, mesh, batch_size, serving=serving)
        flat, treedef = jax.tree_util.tree_flatten_with_path(sub)
        return jax.tree_util.tree_unflatten(
            treedef, [leaf_spec(p, l) for p, l in flat])

    if isinstance(state, KVCache):
        return spec_one(state)
    if isinstance(state, dict):
        return {k: spec_one(v) for k, v in state.items()}
    return spec_one(state)


def batch_specs(batch: dict, mesh: Mesh, batch_size: int) -> dict:
    daxes = _data_axes(mesh)
    dsz = _data_size(mesh)
    data_ok = batch_size >= dsz and batch_size % dsz == 0
    b = (daxes if len(daxes) > 1 else daxes[0]) if data_ok else None
    out = {}
    for k, v in batch.items():
        if v is None:
            continue
        out[k] = P(*([b] + [None] * (v.ndim - 1)))
    return out


def token_spec(mesh: Mesh, batch_size: int) -> P:
    daxes = _data_axes(mesh)
    dsz = _data_size(mesh)
    if batch_size >= dsz and batch_size % dsz == 0:
        return P(daxes if len(daxes) > 1 else daxes[0])
    return P()


def to_named(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
