import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove that every (architecture × input shape × mesh)
combination lowers AND compiles under the production sharding config, and
dump the roofline raw numbers (FLOPs, bytes, per-device memory, collective
traffic) for EXPERIMENTS.md.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 host placeholder devices. (Smoke tests and
benchmarks run in separate processes and see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape decode_32k [--multipod] [--policy lethe|fullkv] \
      [--out experiments/dryrun.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_shape, list_archs, SHAPES
from repro.kernels import ops as kernel_ops
from repro.launch import shardings, specs, steps
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model
from repro.optim import adamw
from repro.roofline import analysis


def lower_case(case: specs.DryrunCase, mesh,
               layers_override: int | None = None) -> dict:
    """Lower + compile one case under ``mesh``; return roofline raw record.

    ``layers_override`` replaces the layer count (keeping full width) — the
    roofline sweep lowers unrolled at two small L values and extrapolates
    linearly, which is *exact* because every per-layer cost is identical
    (see roofline/sweep.py).
    """
    import dataclasses as _dc
    cfg = get_arch(case.arch)
    if layers_override is not None:
        reps = {"n_layers": layers_override}
        if cfg.is_encoder_decoder:
            reps["n_encoder_layers"] = layers_override
        cfg = _dc.replace(cfg, **reps)
    model = build_model(cfg)
    shape = case.shape
    p_sds = specs.params_sds(model, shape)
    p_spec = shardings.param_specs(p_sds, cfg, mesh)
    p_sh = shardings.to_named(p_spec, mesh)

    if case.kind == "train":
        opt_sds = specs.opt_state_sds(p_sds)
        opt_sh = shardings.to_named(shardings.opt_specs(p_spec), mesh)
        b_sds = specs.batch_sds(cfg, shape, with_labels=True)
        b_sh = shardings.to_named(
            shardings.batch_specs(b_sds, mesh, shape.global_batch), mesh)
        fn = steps.make_train_step(
            model, adamw.AdamWConfig(),
            label_offset=(b_sds.get("img_embeds").shape[1]
                          if "img_embeds" in b_sds else 0))
        jfn = jax.jit(fn, in_shardings=(p_sh, opt_sh, b_sh))
        args = (p_sds, opt_sds, b_sds)
    elif case.kind == "prefill":
        b_sds = specs.batch_sds(cfg, shape, with_labels=False)
        b_sh = shardings.to_named(
            shardings.batch_specs(b_sds, mesh, shape.global_batch), mesh)
        fn = steps.make_prefill(model, case.policy, case.policy.capacity)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
        args = (p_sds, b_sds)
    else:  # decode
        st_sds = specs.decode_state_sds(model, shape, case.policy)
        st_sh = shardings.to_named(
            shardings.state_specs(st_sds, cfg, mesh, shape.global_batch),
            mesh)
        tok_sds, pos_sds = specs.decode_inputs_sds(shape)
        tok_sh = jax.sharding.NamedSharding(
            mesh, shardings.token_spec(mesh, shape.global_batch))
        pos_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        fn = steps.make_serve_step(model, case.policy)
        donate = ((1,) if os.environ.get("REPRO_DONATE_STATE") == "1"
                  else ())
        jfn = jax.jit(fn, in_shardings=(p_sh, st_sh, tok_sh, pos_sh),
                      donate_argnums=donate)
        args = (p_sds, st_sds, tok_sds, pos_sds)

    t0 = time.time()
    with mesh:
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()

    n_chips = mesh.size
    hlo = compiled.as_text()
    coll = analysis.collective_bytes(hlo)
    rec = {
        "layers_used": cfg.n_layers,
        "arch": case.arch,
        "shape": shape.name,
        "policy": case.policy.kind,
        "capacity": case.policy.capacity,
        "kind": case.kind,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "n_chips": n_chips,
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": coll,
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "ok": True,
    }
    return rec


def run_case(arch: str, shape_name: str, policy_kind: str,
             multi_pod: bool, out_path: str | None) -> dict:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    case = specs.case_for(cfg, shape, policy_kind)
    if case.skip_reason:
        rec = {"arch": arch, "shape": shape_name, "policy": policy_kind,
               "mesh": "multipod" if multi_pod else "pod",
               "ok": False, "skipped": True, "reason": case.skip_reason}
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        try:
            rec = lower_case(case, mesh)
        except Exception as e:  # a failure here is a bug in the system
            rec = {"arch": arch, "shape": shape_name, "policy": policy_kind,
                   "mesh": "multipod" if multi_pod else "pod",
                   "ok": False, "skipped": False,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--policy", default="lethe")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    # kernels lower through the XLA-native reference on host platforms
    kernel_ops.set_default_impl("ref")

    if args.all:
        combos = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        combos = [(args.arch, args.shape)]
    for arch, shape in combos:
        rec = run_case(arch, shape, args.policy, args.multipod, args.out)
        status = ("OK" if rec.get("ok")
                  else ("SKIP" if rec.get("skipped") else "FAIL"))
        print(f"[{status}] {arch} × {shape} × "
              f"{'multipod' if args.multipod else 'pod'} "
              + (f"flops={rec.get('flops', 0):.3e} "
                 f"temp={rec.get('mem', {}).get('temp_bytes', 0)/2**30:.2f}GiB "
                 f"compile={rec.get('compile_s', 0)}s"
                 if rec.get("ok") else rec.get("reason",
                                               rec.get("error", ""))))
        if not rec.get("ok") and not rec.get("skipped"):
            print(rec.get("trace", ""))


if __name__ == "__main__":
    main()
