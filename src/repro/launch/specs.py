"""ShapeDtypeStruct stand-ins for every (architecture × input-shape) pair —
weak-type-correct, shardable, zero allocation. The dry-run lowers against
these.

Capacity policy for decode shapes (see DESIGN.md):
  * decode_32k  — Lethe capacity 4096 slots (87.5% reduction vs the 32k
    FullKV cache, the paper's operating regime); FullKV variant capacity
    32768 for comparison runs.
  * long_500k   — Lethe capacity 16384. FullKV at 500k exists only for
    natively sub-quadratic archs; for pure full-attention archs the pruned
    cache IS the sub-quadratic mechanism (whisper is skipped outright:
    enc-dec cross-attention is O(dec·enc) regardless).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.core.policy import PolicyConfig, make_policy
from repro.models.api import ModelAPI, build_model
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct

VLM_IMG_TOKENS = 1024
LETHE_CAP_DECODE = 4096
LETHE_CAP_LONG = 16384
PREFILL_CAP = 4096


@dataclasses.dataclass(frozen=True)
class DryrunCase:
    arch: str
    shape: InputShape
    policy: PolicyConfig
    kind: str                   # train | prefill | decode
    skip_reason: str | None = None


def decode_capacity(cfg: ArchConfig, shape: InputShape,
                    policy_kind: str) -> int:
    if policy_kind == "fullkv":
        if cfg.sliding_window and cfg.sub_quadratic:
            return min(shape.seq_len, cfg.sliding_window)
        return shape.seq_len
    return LETHE_CAP_LONG if shape.seq_len > 100_000 else LETHE_CAP_DECODE


def case_for(cfg: ArchConfig, shape: InputShape,
             policy_kind: str = "lethe") -> DryrunCase:
    skip = None
    if shape.name == "long_500k":
        if cfg.is_encoder_decoder:
            skip = ("whisper: enc-dec full attention; no sub-quadratic "
                    "decode variant (DESIGN.md §Arch-applicability)")
        elif (policy_kind == "fullkv" and not cfg.sub_quadratic
              and cfg.has_kv_cache):
            skip = "FullKV@500k unsupported for full-attention archs (OOM "\
                   "by construction — the paper's motivating failure)"
    cap = (decode_capacity(cfg, shape, policy_kind)
           if cfg.has_kv_cache else 8)
    if shape.kind == "prefill":
        cap = PREFILL_CAP if policy_kind != "fullkv" else shape.seq_len
    policy = make_policy(policy_kind, capacity=cap)
    return DryrunCase(arch=cfg.name, shape=shape, policy=policy,
                      kind=shape.kind, skip_reason=skip)


# --------------------------------------------------------------------------
# SDS builders
# --------------------------------------------------------------------------

def batch_sds(cfg: ArchConfig, shape: InputShape, *,
              with_labels: bool) -> dict:
    B, S = shape.global_batch, shape.seq_len
    extra = 1 if with_labels else 0
    out: dict[str, Any] = {}
    if cfg.family == "vlm":
        s_img = min(VLM_IMG_TOKENS, S // 4)
        out["tokens"] = SDS((B, S - s_img + extra), jnp.int32)
        out["img_embeds"] = SDS((B, s_img, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "audio":
        out["tokens"] = SDS((B, S + extra), jnp.int32)
        out["enc_frames"] = SDS((B, cfg.encoder_seq_len, cfg.d_model),
                                jnp.bfloat16)
    else:
        out["tokens"] = SDS((B, S + extra), jnp.int32)
    return out


def model_init_kwargs(cfg: ArchConfig, shape: InputShape) -> dict:
    if cfg.is_encoder_decoder:
        return {"max_positions": max(shape.seq_len + 8, 4096)}
    return {}


def params_sds(model: ModelAPI, shape: InputShape,
               dtype=jnp.bfloat16) -> Any:
    kw = model_init_kwargs(model.cfg, shape)
    return jax.eval_shape(
        lambda k: model.init(k, dtype=dtype, **kw), jax.random.PRNGKey(0))


def opt_state_sds(p_sds: Any) -> Any:
    return jax.eval_shape(adamw.init, p_sds)


def decode_state_sds(model: ModelAPI, shape: InputShape,
                     policy: PolicyConfig, dtype=jnp.bfloat16) -> Any:
    B = shape.global_batch
    kw = {}
    if model.cfg.is_encoder_decoder:
        kw["enc_len"] = model.cfg.encoder_seq_len
    return jax.eval_shape(
        lambda: model.init_decode_state(policy, B, dtype=dtype, **kw))


def decode_inputs_sds(shape: InputShape) -> tuple[Any, Any]:
    return (SDS((shape.global_batch,), jnp.int32), SDS((), jnp.int32))
