"""Step functions lowered by the launcher and the multi-pod dry-run:
``train_step`` (train_4k), ``prefill`` (prefill_32k) and ``serve_step``
(decode_32k / long_500k — ONE new token against a KV cache)."""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.policy import PolicyConfig
from repro.models.api import ModelAPI
from repro.optim import adamw


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  weights: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE. logits [B,S,V] (f32 upcast inside)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if weights is not None:
        return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)
    return jnp.mean(nll)


def make_train_step(model: ModelAPI, opt_cfg: adamw.AdamWConfig,
                    *, aux_weight: float = 0.01,
                    label_offset: int = 0) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics). ``label_offset`` skips image-prefix logits
    for VLM training (logits cover img+text; labels are text-only)."""

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]

        def loss_fn(p):
            logits, aux = model.forward_train(p, batch)
            if label_offset:
                logits = logits[:, label_offset:]
            w = batch.get("loss_weights")
            w = None if w is None else w[:, 1:]
            loss = cross_entropy(logits[:, :-1], tokens[:, 1:], w)
            return loss + aux_weight * aux, loss

        (total, loss), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        new_params, new_opt, metrics = adamw.update(grads, opt_state, params,
                                                    opt_cfg)
        metrics = dict(metrics, loss=loss, total_loss=total)
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(model: ModelAPI, policy: PolicyConfig) -> Callable:
    """serve_step(params, state, token, cur_pos) -> (logits, state):
    one decoded token against the (possibly pruned) cache."""

    def serve_step(params, state, token, cur_pos):
        return model.module.decode_step(params, state, token, cur_pos,
                                        model.cfg, policy)

    return serve_step


def make_prefill(model: ModelAPI, policy: PolicyConfig,
                 capacity: int) -> Callable:
    def prefill_fn(params, batch):
        return model.prefill(params, batch, policy, capacity=capacity,
                             cache_dtype=jnp.bfloat16)
    return prefill_fn
