"""Production mesh definitions (TPU v5e numbers).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link


def _make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax supports them (``jax.sharding.AxisType`` appeared after 0.4.x;
    older versions treat every mesh axis as Auto implicitly)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over however many (possibly fake) devices exist — used by
    sharding unit tests."""
    return _make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
