"""Serving launcher: live traffic through the SLO-aware front door.

Drives the asyncio ``FrontDoor`` (admission control, priorities, deadlines,
load shedding, preemption-to-host) with open-loop Poisson arrivals and
streams tokens per request as they decode — the production-shaped
counterpart of the old static-batch replay.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
      --policy lethe --capacity 64 --slots 4 --prompt-len 48 --gen 64 \
      --requests 16 --arrival-rate 8 --priority-mix 0:0.7,1:0.3 \
      --deadline-ms 60000
"""
from __future__ import annotations

import argparse
import asyncio
import os
import signal
import sys
import time


def _mesh_bootstrap() -> None:
    """``--mesh dp,tp`` on a CPU host needs dp*tp (fake) devices, and the
    ``xla_force_host_platform_device_count`` flag only takes effect BEFORE
    the first jax import — set it here so ``python -m repro.launch.serve
    --mesh 2,4`` just works. A real multi-device backend (TPU) ignores the
    host-platform flag; an explicit XLA_FLAGS wins."""
    if "--mesh" not in sys.argv:
        return
    try:
        dp, tp = (int(x) for x in
                  sys.argv[sys.argv.index("--mesh") + 1].split(","))
    except (IndexError, ValueError):
        return                       # argparse reports the real error later
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={dp * tp}"
        ).strip()


_mesh_bootstrap()

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving import durability as dur_lib
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, FrontDoor,
                                     RetryConfig, ServeRequest)
from repro.serving.meshing import ServingMesh


def parse_priority_mix(spec: str) -> tuple[list[int], list[float]]:
    """``"0:0.7,1:0.3"`` -> priorities + normalised sampling weights."""
    prios, weights = [], []
    for part in spec.split(","):
        p, w = part.split(":")
        prios.append(int(p))
        weights.append(float(w))
    total = sum(weights)
    return prios, [w / total for w in weights]


async def drive(fd: FrontDoor, reqs: list[ServeRequest],
                inter_arrival: list[float], stream: bool) -> None:
    """Open-loop arrival process: each request is submitted at its own
    scheduled time regardless of how the server is keeping up."""

    async def one(req: ServeRequest, delay: float):
        await asyncio.sleep(delay)
        t0 = time.perf_counter()
        if stream:
            n = 0
            async for tok in fd.stream(req):
                n += 1
                if n <= 4:          # keep the console readable
                    print(f"  uid={req.uid} tok[{n - 1}]={tok}")
            comp = fd.completion(req.uid)
        else:
            comp = await fd.submit(req)
        dt = time.perf_counter() - t0
        print(f"uid={comp.uid:3d} pri={comp.priority} "
              f"reason={comp.finish_reason:8s} tokens={len(comp.tokens):3d} "
              f"preempt={comp.preemptions} wall={dt:6.2f}s")

    t, tasks = 0.0, []
    for req, gap in zip(reqs, inter_arrival):
        t += gap
        tasks.append(asyncio.ensure_future(one(req, t)))
    await asyncio.gather(*tasks)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="lethe",
                    choices=["fullkv", "lethe", "h2o", "streaming",
                             "pyramidkv", "lazyeviction", "gkv"])
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--sparse-ratio", type=float, default=4.0)
    ap.add_argument("--recent-ratio", type=float, default=0.3)
    ap.add_argument("--lag-window", type=int, default=64,
                    help="lazyeviction: decode steps a row observes past "
                         "its budget before the lagged eviction fires")
    ap.add_argument("--slots", type=int, default=4,
                    help="live decode slots (continuous batching width)")
    ap.add_argument("--segment-len", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="mean arrivals/s (Poisson); 0 = all at once")
    ap.add_argument("--priority-mix", default="0:1.0",
                    help="priority:weight pairs, e.g. 0:0.7,1:0.3")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request submit->finish deadline")
    ap.add_argument("--decode-timeout-ms", type=float, default=None,
                    help="per-request first-token->finish budget")
    ap.add_argument("--no-stream", action="store_true",
                    help="await whole completions instead of streaming")
    ap.add_argument("--no-shed", action="store_true")
    ap.add_argument("--no-preempt", action="store_true")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the content-hashed prefix store: repeated "
                         "prompt prefixes admit from host RAM instead of "
                         "re-running prefill")
    ap.add_argument("--prefix-cache-mb", type=int, default=256,
                    help="host-RAM bytes cap for the prefix store")
    ap.add_argument("--prefix-templates", type=int, default=4,
                    help="with --prefix-cache: prompts share prefixes "
                         "drawn from this many templates (Zipf-ish reuse); "
                         "0 keeps every prompt unique")
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="serve on a (data=DP, model=TP) device mesh: "
                         "params and KV state shard over kv-heads on "
                         "'model' and slots on 'data' (on a CPU host the "
                         "fake-device XLA flag is set automatically)")
    ap.add_argument("--durability-dir", default=None, metavar="DIR",
                    help="crash-safe serving: write-ahead request journal "
                         "+ periodic bit-exact pool checkpoints under DIR "
                         "(DESIGN.md §Durability)")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="segment boundaries between pool checkpoints "
                         "(0 = journal only)")
    ap.add_argument("--keep-checkpoints", type=int, default=2)
    ap.add_argument("--recover", action="store_true",
                    help="replay the journal in --durability-dir before "
                         "serving: checkpointed requests resume bit-exactly "
                         "from their snapshots, the rest re-prefill")
    ap.add_argument("--no-retry", action="store_true",
                    help="disable the transient-fault retry ladder "
                         "(faulted rows then fail immediately)")
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--restore", default=None)
    args = ap.parse_args()
    if args.recover and not args.durability_dir:
        ap.error("--recover requires --durability-dir")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    init_kw = ({"max_positions": args.prompt_len + args.gen + 8}
               if cfg.is_encoder_decoder else {})
    params = model.init(jax.random.PRNGKey(0), **init_kw)
    if args.restore:
        params = ckpt.restore(args.restore, params)

    pol = make_policy(args.policy, capacity=args.capacity,
                      sparse_ratio=args.sparse_ratio,
                      recent_ratio=args.recent_ratio,
                      lag_window=args.lag_window)
    mesh = ServingMesh.build(args.mesh) if args.mesh else None
    if mesh is not None:
        print(f"mesh: {mesh.topology()}")
    eng = Engine(model, params, pol, mesh=mesh)

    rng = np.random.default_rng(args.seed)
    prios, weights = parse_priority_mix(args.priority_mix)
    dl = args.deadline_ms / 1e3 if args.deadline_ms else None
    dt = args.decode_timeout_ms / 1e3 if args.decode_timeout_ms else None

    def make_prompt() -> np.ndarray:
        return rng.integers(0, cfg.vocab_size,
                            size=args.prompt_len).astype(np.int32)
    if args.prefix_cache and args.prefix_templates > 0:
        # shared-prefix traffic: each prompt = one of N templates plus a
        # short unique tail, so the store sees full AND partial hits.
        # Template lengths are aligned to the 16-token hash block: partial
        # hits only land when the stored prefix length is a chunk-plan
        # boundary of the new prompt (DESIGN.md §Prefix-reuse).
        tmpl_len = max((args.prompt_len * 3 // 4) & ~15, 16)
        tail = max(args.prompt_len - tmpl_len, 1)
        templates = [rng.integers(0, cfg.vocab_size,
                                  size=tmpl_len).astype(np.int32)
                     for _ in range(args.prefix_templates)]

        def make_prompt() -> np.ndarray:    # noqa: F811
            t = templates[int(rng.integers(len(templates)))]
            if rng.random() < 0.5:
                return t.copy()
            return np.concatenate(
                [t, rng.integers(0, cfg.vocab_size, size=tail)]
            ).astype(np.int32)

    adm = AdmissionConfig(enable_shed=not args.no_shed,
                          enable_preempt=not args.no_preempt)
    prefix_cache = None
    if args.prefix_cache:
        from repro.serving.prefix_cache import (PrefixCache,
                                                PrefixCacheConfig)
        prefix_cache = PrefixCache(PrefixCacheConfig(
            max_bytes=args.prefix_cache_mb << 20, block_size=16))

    core_kw = dict(segment_len=args.segment_len, admission=adm,
                   prefix_cache=prefix_cache,
                   retry=None if args.no_retry
                   else RetryConfig(max_retries=args.max_retries,
                                    backoff_base_s=0.05))
    dur_cfg = None
    if args.durability_dir:
        dur_cfg = dur_lib.DurabilityConfig(
            root=args.durability_dir,
            checkpoint_every=args.checkpoint_every,
            keep_checkpoints=args.keep_checkpoints)

    core = None
    uid0 = 0
    if args.recover:
        core, report = dur_lib.recover(eng, args.durability_dir,
                                       batch_slots=args.slots,
                                       durability=dur_cfg, **core_kw)
        uid0 = max(report["known_uids"], default=-1) + 1
        print(f"recovery: records={report['journal_records']} "
              f"truncated_bytes={report['journal_truncated_bytes']} "
              f"resumed={report['resumed_from_checkpoint']} "
              f"replayed={report['replayed_from_prompt']} "
              f"checkpoint={report['checkpoint_seq']}")
        for uid, toks in sorted(report["durable_tokens"].items()):
            state = report["finished"].get(uid, "outstanding")
            print(f"  uid={uid}: {len(toks)} durable tokens "
                  f"({state}) — replayable to a reconnecting client")

    reqs = [ServeRequest(
        uid=uid0 + i, prompt=make_prompt(),
        max_new_tokens=args.gen,
        priority=int(rng.choice(prios, p=weights)),
        deadline_s=dl, decode_timeout_s=dt)
        for i in range(args.requests)]
    gaps = (list(rng.exponential(1.0 / args.arrival_rate,
                                 size=args.requests))
            if args.arrival_rate > 0 else [0.0] * args.requests)

    async def serve():
        if core is not None:
            fd_ctx = FrontDoor(eng, args.slots, core=core)
        else:
            fd_ctx = FrontDoor(eng, batch_slots=args.slots,
                               durability=dur_cfg, **core_kw)
        drained = None
        stop = asyncio.Event()

        def on_signal(name: str) -> None:
            # second signal = hard exit; first = graceful drain below
            if stop.is_set():
                os._exit(1)
            print(f"\n[{name}] graceful drain: halting after the "
                  f"in-flight segment ...")
            stop.set()

        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            loop.add_signal_handler(sig, on_signal, sig.name)
        async with fd_ctx as fd:
            t0 = time.perf_counter()
            work = asyncio.ensure_future(
                drive(fd, reqs, gaps, stream=not args.no_stream))
            stopper = asyncio.ensure_future(stop.wait())
            await asyncio.wait({work, stopper},
                               return_when=asyncio.FIRST_COMPLETED)
            if stop.is_set():
                work.cancel()                 # pending arrivals never land
                await asyncio.gather(work, return_exceptions=True)
                await fd.halt()
                drained = fd.core.shutdown(
                    checkpoint=dur_cfg is not None)
            else:
                stopper.cancel()
                await fd.drain()
                # recovered requests have no awaiting client future —
                # hold the door open until the pump parks on an empty core
                while not fd.quiesced and not stop.is_set():
                    await asyncio.sleep(0.05)
                if dur_cfg is not None and not stop.is_set():
                    fd.core.shutdown(checkpoint=False)  # seal: clean exit
            wall = time.perf_counter() - t0
            s = fd.core.run_summary()
        for sig in (signal.SIGINT, signal.SIGTERM):
            loop.remove_signal_handler(sig)
        if drained is not None:
            print(f"drained: live={drained['live']} "
                  f"queued={drained['queued']} "
                  f"staged={drained['staged']} "
                  f"checkpoint_seq={drained['checkpoint_seq']}")
            if dur_cfg is not None:
                print(f"restart with:  --recover --durability-dir "
                      f"{args.durability_dir}")
        print(f"\npolicy={args.policy} capacity={args.capacity} "
              f"slots={args.slots} kv_format={s['kv_format']}")
        print(f"completed={s['completed']} reasons={s['finish_reasons']}")
        print(f"preempted={s['preempted']} max_queue={s['max_queue_depth']} "
              f"peak_pressure={s['peak_pressure']:.2f}")
        if s["failed"] or s["retries"]:
            print(f"faults: details={s['failure_details']} "
                  f"retries={s['retries']} "
                  f"quarantined={s['quarantined_slots']}")
        if s.get("durability"):
            ds = s["durability"]
            print(f"durability: journal_appends={ds['journal_appends']} "
                  f"tokens_logged={ds['tokens_logged']} "
                  f"checkpoints={ds['checkpoints_written']} "
                  f"ckpt_mean={ds['checkpoint_seconds_mean'] * 1e3:.1f}ms "
                  f"sealed={ds['sealed']}")
        if s.get("prefix_cache"):
            pcs = s["prefix_cache"]
            print(f"prefix store: hit_rate={pcs['hit_rate']:.2f} "
                  f"(full={pcs['full_hits']} partial={pcs['partial_hits']} "
                  f"miss={pcs['misses']}) entries={pcs['entries']} "
                  f"bytes={pcs['bytes_used']}")
        ok = [c for c in fd.core.completed
              if c.finish_reason in ("eos", "length")]
        toks = sum(len(c.tokens) for c in ok)
        n_expected = len(reqs) + (report["outstanding"] if args.recover
                                  else 0)
        print(f"goodput={toks / max(wall, 1e-9):.1f} tok/s over {wall:.2f}s "
              f"({len(ok)}/{n_expected} requests healthy)")

    asyncio.run(serve())


if __name__ == "__main__":
    main()
