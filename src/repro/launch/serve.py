"""Serving launcher: batched generation under any cache policy.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
      --policy lethe --capacity 64 --batch 4 --prompt-len 48 --gen 64
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="lethe",
                    choices=["fullkv", "lethe", "h2o", "streaming",
                             "pyramidkv"])
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--sparse-ratio", type=float, default=4.0)
    ap.add_argument("--recent-ratio", type=float, default=0.3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--restore", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    init_kw = ({"max_positions": args.prompt_len + args.gen + 8}
               if cfg.is_encoder_decoder else {})
    params = model.init(jax.random.PRNGKey(0), **init_kw)
    if args.restore:
        params = ckpt.restore(args.restore, params)

    pol = make_policy(args.policy, capacity=args.capacity,
                      sparse_ratio=args.sparse_ratio,
                      recent_ratio=args.recent_ratio)
    eng = Engine(model, params, pol)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "audio":
        batch["enc_frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(5), (args.batch, 16, cfg.d_model))
    if cfg.family == "vlm":
        batch["img_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(5), (args.batch, 8, cfg.d_model))

    res = eng.generate(batch, args.gen, temperature=args.temperature,
                       trace_live=True)
    print(f"policy={args.policy} capacity={args.capacity}")
    print(f"prefill={res.prefill_seconds:.2f}s decode={res.decode_seconds:.2f}s "
          f"tokens/s={res.tokens_per_second:.1f}")
    print(f"cache_bytes={res.cache_bytes/2**20:.2f} MiB")
    if res.live_token_trace:
        tr = res.live_token_trace
        print(f"live-token trace: start={tr[0]} peak={max(tr)} end={tr[-1]}")
    print("first row tokens:", res.tokens[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
