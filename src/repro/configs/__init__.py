"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import ArchConfig, get_arch, list_archs, register
from repro.configs.shapes import SHAPES, InputShape, get_shape

# Side-effect registration of the 10 assigned architectures.
from repro.configs import (  # noqa: F401
    rwkv6_7b,
    arctic_480b,
    recurrentgemma_2b,
    command_r_35b,
    mixtral_8x7b,
    qwen2_5_32b,
    gemma2_27b,
    granite_20b,
    qwen2_vl_2b,
    whisper_large_v3,
)

__all__ = [
    "ArchConfig", "get_arch", "list_archs", "register",
    "SHAPES", "InputShape", "get_shape",
]
