"""RWKV6 "Finch" 7B — attention-free SSM with data-dependent decay
[arXiv:2404.05892]. No KV cache exists; Lethe is inapplicable (see
DESIGN.md §Arch-applicability) — included as the attention-free reference."""
from repro.configs.base import RWKV, ArchConfig, register

RWKV6_7B = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    source="Finch: RWKV-6 [arXiv:2404.05892]",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # wkv heads = d_model / head_size
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=(RWKV,),
    rwkv_head_size=64,
    use_rope=False,
    act="relu_sq",           # RWKV channel-mix uses squared ReLU
    norm_style="layernorm",
))
