"""Cohere Command-R 35B — dense GQA, parallel attention+FFN block, no bias
[hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ArchConfig, register

COMMAND_R_35B = register(ArchConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22528,
    vocab_size=256000,
    qkv_bias=False,
    parallel_block=True,
    norm_style="layernorm",
    rope_theta=8e6,
    tie_embeddings=True,
))
