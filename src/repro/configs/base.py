"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig`` registered under its
``--arch`` id. ``reduced()`` produces the CPU smoke-test variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts) as required by the assignment.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds used by heterogeneous stacks.
ATTN = "attn"          # global self-attention
LOCAL_ATTN = "local"   # sliding-window self-attention
RGLRU = "rglru"        # RecurrentGemma RG-LRU recurrent block
RWKV = "rwkv"          # RWKV6 time-mix block


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation for the config numbers
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads

    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None   # gemma2
    final_logit_softcap: Optional[float] = None  # gemma2
    sliding_window: Optional[int] = None         # mixtral SWA / local layers
    local_global_period: int = 0     # gemma2: 2 -> alternate local/global
    rope_theta: float = 10000.0
    use_rope: bool = True
    mrope: bool = False              # qwen2-vl M-RoPE (3 position streams)
    mrope_sections: Tuple[int, ...] = ()

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_d_ff: int = 0                # expert hidden dim (arctic differs)
    dense_residual_d_ff: int = 0     # arctic: parallel dense FFN
    moe_capacity_factor: float = 1.25  # GShard-style capacity (drops excess)

    # --- recurrent / hybrid -------------------------------------------------
    block_pattern: Tuple[str, ...] = ()  # per-layer kinds; () -> all ATTN
    lru_width: int = 0               # rglru recurrence width (0 -> d_model)
    conv_width: int = 4              # rglru temporal conv
    rwkv_head_size: int = 64

    # --- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper native frame count

    # --- misc ----------------------------------------------------------------
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    norm_style: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu | relu_sq
    gated_mlp: bool = True           # SwiGLU/GeGLU (3 mats) vs plain (2 mats)
    parallel_block: bool = False     # command-r style parallel attn+ffn
    sandwich_norm: bool = False      # gemma2 post-sublayer norms
    emb_scale_by_sqrt_dim: bool = False  # gemma-style input scaling
    frontend: str = "none"           # none | audio_stub | vision_stub

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # -- derived -------------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.local_global_period:
            # gemma2: layer 0 local, 1 global, ... (period 2)
            return tuple(
                LOCAL_ATTN if (i % self.local_global_period) != self.local_global_period - 1
                else ATTN
                for i in range(self.n_layers)
            )
        return tuple(ATTN for _ in range(self.n_layers))

    @property
    def is_attention_free(self) -> bool:
        return all(k == RWKV for k in self.layer_kinds)

    @property
    def has_kv_cache(self) -> bool:
        return any(k in (ATTN, LOCAL_ATTN) for k in self.layer_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True when a 500k-token decode is natively feasible (no unbounded
        full-attention cache)."""
        kinds = self.layer_kinds
        if all(k in (RWKV, RGLRU, LOCAL_ATTN) for k in kinds):
            return True
        if self.local_global_period:
            return False  # global layers are full attention (gemma2)
        # archs whose attention layers are all windowed (mixtral SWA)
        return all(
            (k != ATTN) or (self.sliding_window is not None) for k in kinds
        )

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS roofline term)."""
        d, L = self.d_model, self.n_layers
        dh, hq, hkv = self.d_head, self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        for kind in self.layer_kinds:
            if kind in (ATTN, LOCAL_ATTN):
                per_layer += d * dh * (hq + 2 * hkv) + hq * dh * d
            elif kind == RGLRU:
                w = self.lru_width or d
                per_layer += 2 * d * w + self.conv_width * w + 2 * w * w // 8 + w * d
            elif kind == RWKV:
                per_layer += 4 * d * d + 2 * d  # time-mix r,k,v,o + decay
            # FFN
            if self.n_experts:
                per_layer += self.n_experts * 3 * d * self.moe_d_ff / len(self.layer_kinds) * 0
        # FFN counted uniformly below
        ffn = 0
        if self.n_experts:
            ffn = L * (self.n_experts * 3 * d * (self.moe_d_ff or self.d_ff)
                       + self.n_experts * d)
            if self.dense_residual_d_ff:
                ffn += L * 3 * d * self.dense_residual_d_ff
        else:
            mult = 3 if self.gated_mlp else 2
            ffn = L * mult * d * self.d_ff
        enc = 0
        if self.is_encoder_decoder:
            enc = self.n_encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            # decoder cross-attention
            enc += L * (d * dh * (hq + 2 * hkv) + hq * dh * d)
        return int(emb + per_layer + ffn + enc)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        all_experts = L * self.n_experts * 3 * d * (self.moe_d_ff or self.d_ff)
        active_experts = L * self.n_experts_per_tok * 3 * d * (self.moe_d_ff or self.d_ff)
        return int(full - all_experts + active_experts)

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant of the same family."""
        n_layers = min(self.n_layers, 2)
        if self.block_pattern:
            n_layers = min(self.n_layers, len(self.block_pattern))
        d_model = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads, 2))
        changes = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=d_model // n_heads,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else None,
            lru_width=min(self.lru_width, d_model) if self.lru_width else 0,
            rwkv_head_size=min(self.rwkv_head_size, d_model // n_heads),
            encoder_seq_len=32,
        )
        if self.n_experts:
            changes.update(
                n_experts=min(self.n_experts, 4),
                # no token dropping at toy scale: keeps incremental decode
                # exactly equal to the parallel forward (test invariant)
                moe_capacity_factor=float(self.n_experts),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 128),
                dense_residual_d_ff=min(self.dense_residual_d_ff, 128)
                if self.dense_residual_d_ff else 0,
            )
        if self.is_encoder_decoder:
            changes["n_encoder_layers"] = min(self.n_encoder_layers, 2)
        if self.mrope:
            sec = self.d_head // n_heads  # keep sections summing to d_head//2
            dh = d_model // n_heads
            changes["mrope_sections"] = (dh // 4, dh // 8, dh // 8)
        return dataclasses.replace(self, **changes)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    # import side-effect registration
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
