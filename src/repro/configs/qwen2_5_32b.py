"""Qwen2.5 32B — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family]."""
from repro.configs.base import ArchConfig, register

QWEN2_5_32B = register(ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B (family card)",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
))
