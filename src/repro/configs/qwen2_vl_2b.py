"""Qwen2-VL 2B — VLM text backbone with M-RoPE and dynamic-resolution vision
frontend (stubbed: ``input_specs`` supplies precomputed patch embeddings)
[arXiv:2409.12191]."""
from repro.configs.base import ArchConfig, register

QWEN2_VL_2B = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="Qwen2-VL [arXiv:2409.12191]",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),   # temporal/height/width, sums to d_head//2
    rope_theta=1e6,
    frontend="vision_stub",
    tie_embeddings=True,
))
