"""Snowflake Arctic 480B — dense-MoE hybrid: 128-expert top-2 MoE with a
parallel dense residual MLP [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig, register

ARCTIC_480B = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,            # GQA kv=8
    d_head=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    n_experts_per_tok=2,
    moe_d_ff=4864,
    dense_residual_d_ff=4864,
    rope_theta=1e6,
))
