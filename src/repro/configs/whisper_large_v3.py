"""Whisper large-v3 — encoder-decoder audio backbone; mel+conv frontend is
stubbed (``input_specs`` supplies precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.configs.base import ArchConfig, register

WHISPER_LARGE_V3 = register(ArchConfig(
    name="whisper-large-v3",
    family="audio",
    source="Whisper [arXiv:2212.04356]",
    n_layers=32,               # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,             # full MHA
    d_head=64,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    encoder_seq_len=1500,
    use_rope=False,            # learned absolute positions
    norm_style="layernorm",
    act="gelu",
    gated_mlp=False,           # plain 2-matrix MLP
    frontend="audio_stub",
    tie_embeddings=True,
))
