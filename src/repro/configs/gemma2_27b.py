"""Gemma2 27B — alternating local/global attention with logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ArchConfig, register

GEMMA2_27B = register(ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="Gemma 2 [arXiv:2408.00118]",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=36864,
    vocab_size=256000,
    local_global_period=2,     # local, global, local, global, ...
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    sandwich_norm=True,
    emb_scale_by_sqrt_dim=True,
    tie_embeddings=True,
))
