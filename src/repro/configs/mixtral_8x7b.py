"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.configs.base import ArchConfig, register

MIXTRAL_8X7B = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    source="Mixtral of Experts [arXiv:2401.04088]",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    n_experts_per_tok=2,
    moe_d_ff=14336,
    sliding_window=4096,
    rope_theta=1e6,
))
