"""RecurrentGemma 2B (Griffin) — RG-LRU recurrent blocks + local attention,
2:1 pattern [arXiv:2402.19427]."""
from repro.configs.base import LOCAL_ATTN, RGLRU, ArchConfig, register

RECURRENTGEMMA_2B = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="RecurrentGemma / Griffin [arXiv:2402.19427]",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,            # GQA kv=1 (MQA) on the local-attention layers
    d_head=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    lru_width=2560,
    conv_width=4,
    sliding_window=2048,
    act="gelu",
    emb_scale_by_sqrt_dim=True,
))
