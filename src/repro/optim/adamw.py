"""AdamW with decoupled weight decay, global-norm clipping and warmup-cosine
schedule — pure JAX (no optax in this environment)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    step_f = step.astype(jnp.float32)
    warm = step_f / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step_f - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step_f < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state: AdamWState, params, cfg: AdamWConfig
           ) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    step = state.step + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, n):
        mhat = m / b1c
        nhat = n / b2c
        step_out = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            step_out = step_out + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_out).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), metrics
