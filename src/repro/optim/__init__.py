from repro.optim.adamw import AdamWConfig, AdamWState, init, update, schedule

__all__ = ["AdamWConfig", "AdamWState", "init", "update", "schedule"]
