"""Request scheduler: continuous batching over a fixed-width live batch.

The serving shape of the paper's multi-batch experiments (Tables 2–3: batch
sizes 1..32 under memory pressure), under the ROADMAP's mixed-traffic regime
where request lengths differ wildly. Two scheduling modes:

* ``run()`` — **continuous batching** (the default). The live decode state
  has ``batch_slots`` slots; decode proceeds in fixed segments of
  ``segment_len`` steps (one ``lax.scan`` each, per-row positions). Every
  request walks the lifecycle

      QUEUED -> PREFILLING -> DECODING -> FINISHED (EOS or length)

  and between segments finished slots are retired (``Engine.release_slot``)
  and queued requests admitted into them (``Engine.admit_slot``: a solo B=1
  prefill inserted into the live state). Because pruning, RASR scores,
  sparsity estimates and per-layer budgets are all per-row, a request's
  tokens are exactly those of a solo ``Engine.generate`` run — neighbors
  and admission order cannot change them; only latency changes.

* ``run_lockstep()`` — the old run-to-completion mode kept as the
  throughput baseline: requests are packed into right-aligned padded
  batches and every batch decodes until its *longest* request finishes, so
  one long reasoning request holds all slots hostage and finished rows burn
  kernel work on dead slots. ``benchmarks/serving_traffic.py`` measures the
  gap.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Iterable

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine

# Request lifecycle states (per-uid log in ``Scheduler.lifecycle``).
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
PREEMPTED = "preempted"
FINISHED = "finished"

# Typed terminal reasons (``Completion.finish_reason``). Every request ends
# in exactly one of these; the serving front door's overload and fault paths
# are distinguishable from healthy completion by reason alone:
#   eos/length — healthy completion
#   timeout    — deadline or decode-timeout exceeded (queued or mid-decode)
#   shed       — dropped from the queue under load shedding
#   rejected   — refused at admission (queue full, over pressure, or an
#                inadmissible prompt under this policy)
#   failed     — per-request fault (non-finite logits / injected row fault);
#                the rest of the batch keeps decoding
FINISH_REASONS = ("eos", "length", "timeout", "shed", "rejected", "failed")

# Typed failure taxonomy (``Completion.failure_detail``; set only when
# ``finish_reason == "failed"``). Chaos/robustness tests assert on these
# instead of string-matching a bare "failed":
#   nan_logits        — non-finite decode logits (real or chaos-injected)
#   row_fault         — flagged per-row kernel fault mid-segment
#   retry_exhausted   — transient-fault retry ladder hit its cap; the
#                       slot was quarantined
#   prefill_nonfinite — poisoned prompt: non-finite logits at prefill,
#                       the row never went live
FAILURE_DETAILS = ("nan_logits", "row_fault", "retry_exhausted",
                   "prefill_nonfinite")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray          # generated tokens (incl. EOS if emitted)
    latency_steps: int          # == len(tokens)
    finish_reason: str = "length"       # one of FINISH_REASONS: "eos" |
    #                             "length" | "timeout" | "shed" |
    #                             "rejected" | "failed" (see the block
    #                             comment above for what each one means)
    queue_wait_s: float = 0.0   # submit -> prefill start
    ttft_s: float = 0.0         # submit -> first token (incl. queue wait)
    decode_steps: int = 0       # decode steps after the prefill token
    tokens_per_second: float = 0.0      # generated tokens / residency time
    ttft_steps: int = 0         # scheduler decode steps executed before the
    #                             first token (the wall-clock-free TTFT)
    kv_format: str = "bf16"     # cache storage format this run served under
    cache_bytes: int = 0        # physical bytes of the live decode state
    #                             (K/V payloads + dequant scales + metadata)
    priority: int = 0           # request priority (higher = more urgent)
    preemptions: int = 0        # times this request was preempted to host
    queue_depth: int = 0        # queue depth observed at submission
    prefix_hit: str = "miss"    # prefix-store outcome at admission:
    #                             "full" (stored rows inserted, no prefill),
    #                             "partial" (suffix-only resumed prefill),
    #                             or "miss" (cold prefill / store disabled)
    failure_detail: str | None = None   # one of FAILURE_DETAILS when
    #                             finish_reason == "failed"; None otherwise
    retries: int = 0            # transient-fault snapshot-rollback retries
    #                             this request survived (front door only)


@dataclasses.dataclass
class _Slot:
    req: Request
    tokens: list
    admit_ts: float
    ttft: float = 0.0
    ttft_steps: int = 0
    prefix_hit: str = "miss"


@dataclasses.dataclass
class _PrefillGroup:
    """One in-flight chunked admission group: the engine job plus the
    slots it reserved and the requests destined for them."""
    job: object                     # engine.PrefillJob
    assignments: list               # [(slot_id, Request)]
    admit_ts: float
    prefix_hit: str = "miss"        # "partial" for a resumed suffix job


class Scheduler:
    def __init__(self, engine: Engine, batch_slots: int, pad_token: int = 0,
                 segment_len: int = 32, eos_id: int | None = None,
                 track_occupancy: bool = False,
                 prefill_chunk_size: int | None = None,
                 prefix_cache=None, mesh=None):
        self.engine = engine
        # Mesh-sharded serving: the engine owns the mesh (params/state
        # placement + the shard_map decode dispatch); the scheduler only
        # needs it for the prefix-store fingerprint and run telemetry. An
        # explicit ``mesh`` kwarg is accepted for end-to-end plumbing but
        # must agree with the engine's.
        if mesh is not None and mesh is not engine.mesh:
            raise ValueError(
                "Scheduler(mesh=...) must be the engine's own ServingMesh "
                "(pass mesh= to Engine; the scheduler adopts it)")
        self.mesh = engine.mesh
        # Content-hashed prefix store (serving/prefix_cache.PrefixCache):
        # admission probes it before prefilling — full hits insert stored
        # rows, partial hits resume suffix-only prefill, misses prefill
        # cold and are captured. None = recompute every admission.
        self.prefix_cache = prefix_cache
        if prefix_cache is not None:
            from repro.serving.prefix_cache import prefix_fingerprint
            self._fp = prefix_fingerprint(
                engine.policy, engine.cache_dtype,
                arch=engine.model.cfg.name,
                mesh=(self.mesh.topology_token()
                      if self.mesh is not None else ""))
        self.batch_slots = batch_slots
        self.pad_token = pad_token
        self.segment_len = segment_len
        self.eos_id = eos_id
        self.track_occupancy = track_occupancy
        # Chunked (stall-free) admission: prefill advances at most ONE chunk
        # of this many tokens per decode segment while any row is decoding,
        # so no live request ever waits on a whole prompt. None = the
        # original whole-prompt admission.
        self.prefill_chunk_size = prefill_chunk_size
        # Pad admission groups to the full slot width so every refill wave
        # shares one program per chunk shape (compile-friendly). Turn off
        # when per-chunk FLOPs matter more than retraces (dummy rows cost
        # real compute on small groups).
        self.pad_admission_rows = True
        self.queue: collections.deque[Request] = collections.deque()
        self.completed: list[Completion] = []
        self.lifecycle: dict[int, list[str]] = {}
        self._submit_ts: dict[int, float] = {}
        # telemetry (filled by run()): per-segment live-slot counts and the
        # max per-slot cache occupancy ever observed across refills
        self.occupancy_trace: list[int] = []
        self.max_slot_tokens: int = 0
        # chunked-admission telemetry: one record per segment boundary —
        # how many live decode rows existed and how many prefill chunk
        # steps ran before the next segment (the stall-bound witness: the
        # chunk count is <= 1 whenever live > 0)
        self.prefill_boundary_trace: list[dict] = []
        self._decode_steps = 0
        # per-segment wall-clock gaps: (rows live BEFORE the boundary,
        # seconds since the previous segment finished). The gap covers the
        # boundary work that preceded the segment, so it is the inter-token
        # latency an already-decoding row experiences across an admission
        # wave (benchmarks take the p95 over live>0 entries; rows admitted
        # at the boundary itself are waiting on TTFT, not ITL, and don't
        # tag the gap).
        self.segment_gap_trace: list[tuple[int, float]] = []
        # physical-bytes stamp for Completion metrics (static per engine
        # config; refreshed from the live state at the start of each run)
        self._kv_format = getattr(engine.policy, "kv_format", "bf16")
        self._cache_bytes = 0
        # robustness counters (ISSUE 6): always present so overload runs
        # are distinguishable from healthy ones in every run summary —
        # the plain scheduler never sheds/preempts/times out, so its
        # counters stay structurally zero.
        self.max_queue_depth = 0
        self._submit_depth: dict[int, int] = {}

    def submit(self, reqs: Iterable[Request]) -> None:
        now = time.perf_counter()
        for r in reqs:
            self.queue.append(r)
            self._submit_ts[r.uid] = now
            self._submit_depth[r.uid] = len(self.queue)
            self.max_queue_depth = max(self.max_queue_depth,
                                       len(self.queue))
            self.lifecycle[r.uid] = [QUEUED]

    def run_summary(self) -> dict:
        """Aggregate robustness counters over ``completed`` — one shape
        shared with the front door so benchmark config blocks can record
        overload behavior uniformly."""
        by_reason = {r: 0 for r in FINISH_REASONS}
        details: dict[str, int] = {}
        for c in self.completed:
            by_reason[c.finish_reason] = by_reason.get(c.finish_reason,
                                                       0) + 1
            if c.failure_detail is not None:
                details[c.failure_detail] = details.get(c.failure_detail,
                                                        0) + 1
        return {
            "completed": len(self.completed),
            "finish_reasons": by_reason,
            "shed": by_reason["shed"],
            "preempted": sum(c.preemptions for c in self.completed),
            "timeout": by_reason["timeout"],
            "failed": by_reason["failed"],
            "failure_details": details,
            "retries": sum(c.retries for c in self.completed),
            "rejected": by_reason["rejected"],
            "max_queue_depth": self.max_queue_depth,
            "decode_steps": self._decode_steps,
            "kv_format": self._kv_format,
            "mesh": (self.mesh.topology() if self.mesh is not None
                     else None),
            "prefix_full_hits": sum(c.prefix_hit == "full"
                                    for c in self.completed),
            "prefix_partial_hits": sum(c.prefix_hit == "partial"
                                       for c in self.completed),
            "prefix_cache": (self.prefix_cache.stats()
                             if self.prefix_cache is not None else None),
        }

    # ---- continuous batching ---------------------------------------------

    def _finish(self, slot: _Slot, reason: str) -> None:
        now = time.perf_counter()
        r = slot.req
        toks = np.asarray(slot.tokens, np.int32)
        resid = max(now - slot.admit_ts, 1e-9)
        self.lifecycle[r.uid].append(FINISHED)
        self.completed.append(Completion(
            uid=r.uid, tokens=toks, latency_steps=len(toks),
            finish_reason=reason,
            queue_wait_s=slot.admit_ts - self._submit_ts[r.uid],
            ttft_s=slot.ttft - self._submit_ts[r.uid],
            decode_steps=len(toks) - 1,
            tokens_per_second=len(toks) / resid,
            ttft_steps=slot.ttft_steps,
            kv_format=self._kv_format, cache_bytes=self._cache_bytes,
            queue_depth=self._submit_depth.get(r.uid, 0),
            prefix_hit=slot.prefix_hit))

    def _activate(self, slots, tok, pos, done, i: int, r: Request, first: int,
                  admit_ts: float, prefix_hit: str = "miss") -> None:
        """Bring one freshly admitted request live in slot ``i`` (or finish
        it immediately: EOS on the very first token / a 1-token budget)."""
        slot = _Slot(req=r, tokens=[int(first)], admit_ts=admit_ts,
                     ttft=time.perf_counter(), ttft_steps=self._decode_steps,
                     prefix_hit=prefix_hit)
        if self.eos_id is not None and first == self.eos_id:
            self._finish(slot, "eos")
        elif r.max_new_tokens <= 1:
            self._finish(slot, "length")
        else:
            self.lifecycle[r.uid].append(DECODING)
            slots[i] = slot
            tok[i] = first
            pos[i] = len(r.prompt)
            done[i] = False

    # ---- prefix reuse (serving/prefix_cache.py) --------------------------

    def _capture_prefix(self, r: Request, rows, j: int, first: int) -> None:
        """Snapshot row ``j`` of freshly finalized ``rows`` into the prefix
        store (the extract_slots host copy is bit-exact, so a later full
        hit re-admits the same bytes a recomputation would produce)."""
        if self.prefix_cache is None:
            return
        from repro.core import cache as cache_lib
        self.prefix_cache.insert(self._fp, r.prompt,
                                 cache_lib.extract_slots(rows, [j]),
                                 int(first))

    def _try_prefix_admit(self, state, slots, tok, pos, done, i: int,
                          r: Request, admit_ts: float):
        """Probe the prefix store for one pending request. Returns
        (state', True) when the request was admitted from the store (full
        hit: stored rows inserted; partial hit: suffix-only resumed
        prefill); (state, False) sends it down the cold path."""
        from repro.core import cache as cache_lib
        hit = self.prefix_cache.lookup(self._fp, r.prompt)
        if hit is None:
            return state, False
        if hit.full:
            state = cache_lib.insert_slots(state, [i], hit.entry.rows)
            self._activate(slots, tok, pos, done, i, r,
                           hit.entry.first_token, admit_ts,
                           prefix_hit="full")
            return state, True
        suffix = np.asarray(r.prompt[hit.prefix_len:], np.int32)[None, :]
        try:
            logits, rows = self.engine.resume_prefill_rows(
                hit.entry.rows, {"tokens": suffix},
                s_prefix=hit.prefix_len,
                chunk_size=self.prefill_chunk_size or 32)
        except ValueError:
            return state, False          # inadmissible resume: go cold
        first = int(np.asarray(logits).argmax(axis=-1)[0])
        state = cache_lib.insert_slots(state, [i], rows)
        self._capture_prefix(r, rows, 0, first)
        self._activate(slots, tok, pos, done, i, r, first, admit_ts,
                       prefix_hit="partial")
        return state, True

    def _open_prefill_groups(self, state, slots, tok, pos, done,
                             reserved: set) -> tuple:
        """Reserve free slots for queued requests and open chunked-prefill
        jobs — one job per (FIFO-popped) equal-length group, padded to the
        full slot width so a refill wave of any group size reuses one
        program per chunk shape. With a prefix store, full hits admit
        immediately (no job) and partial hits open single-row resumed jobs
        that stream only the suffix. Returns (state', groups)."""
        free = [i for i in range(self.batch_slots)
                if slots[i] is None and i not in reserved]
        pending = []
        while self.queue and free:
            pending.append((free.pop(0), self.queue.popleft()))
        groups = []
        by_len: dict[int, list] = {}
        admit_ts = time.perf_counter()
        for i, r in pending:
            self.lifecycle[r.uid].append(PREFILLING)
            if self.prefix_cache is not None:
                hit = self.prefix_cache.lookup(self._fp, r.prompt)
                if hit is not None and hit.full:
                    from repro.core import cache as cache_lib
                    state = cache_lib.insert_slots(state, [i],
                                                   hit.entry.rows)
                    self._activate(slots, tok, pos, done, i, r,
                                   hit.entry.first_token, admit_ts,
                                   prefix_hit="full")
                    continue
                if hit is not None:
                    suffix = np.asarray(r.prompt[hit.prefix_len:],
                                        np.int32)[None, :]
                    try:
                        job = self.engine.start_prefill_resumed(
                            hit.entry.rows, {"tokens": suffix},
                            s_prefix=hit.prefix_len,
                            chunk_size=self.prefill_chunk_size)
                    except ValueError:
                        pass             # inadmissible resume: go cold
                    else:
                        groups.append(_PrefillGroup(
                            job=job, assignments=[(i, r)],
                            admit_ts=admit_ts, prefix_hit="partial"))
                        continue
            by_len.setdefault(len(r.prompt), []).append((i, r))
        for _, group in sorted(by_len.items()):
            prompts = np.stack([r.prompt for _, r in group]).astype(np.int32)
            try:
                job = self.engine.start_prefill_chunked(
                    {"tokens": jnp.asarray(prompts)},
                    chunk_size=self.prefill_chunk_size,
                    pad_rows_to=(self.batch_slots if self.pad_admission_rows
                                 else None))
            except ValueError:
                # inadmissible under this policy (prompt exceeds capacity
                # and nothing can be evicted): reject the requests rather
                # than abort the run — other in-flight requests must not
                # lose their tokens to one bad arrival
                now = time.perf_counter()
                for _, r in group:
                    self.lifecycle[r.uid].append(FINISHED)
                    self.completed.append(Completion(
                        uid=r.uid, tokens=np.zeros((0,), np.int32),
                        latency_steps=0, finish_reason="rejected",
                        queue_wait_s=admit_ts - self._submit_ts[r.uid],
                        ttft_s=now - self._submit_ts[r.uid],
                        queue_depth=self._submit_depth.get(r.uid, 0)))
                continue
            groups.append(_PrefillGroup(job=job, assignments=group,
                                        admit_ts=admit_ts))
        return state, groups

    def run(self) -> list[Completion]:
        """Drain the queue with continuous batching; returns completions
        (uid-ordered). Greedy decoding (the deterministic serving mode).

        With ``prefill_chunk_size`` set, admission is *stall-free*: a
        queued request's prefill advances at most one chunk per decode
        segment while any row is decoding (Sarathi-style interleave), and
        runs back-to-back only when no decode would be stalled by it.
        """
        eng = self.engine
        B = self.batch_slots
        eos = self.eos_id
        state = eng.new_decode_state(B)
        from repro.serving.engine import _cache_stats
        stats = _cache_stats(state)
        self._cache_bytes = stats["cache_bytes"]
        self._kv_format = stats["kv_format"]
        slots: list[_Slot | None] = [None] * B
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        done = np.ones((B,), bool)          # empty slots are frozen
        jobs: list[_PrefillGroup] = []      # FIFO chunked-admission groups
        self._decode_steps = 0
        t_seg = time.perf_counter()

        while self.queue or jobs or any(s is not None for s in slots):
            live_pre = sum(s is not None for s in slots)
            if self.prefill_chunk_size is None:
                # -- whole-prompt admission (the original path): admit
                # queued requests into free slots, grouped by prompt length
                # so one prefill + one donated insert covers a whole refill
                # wave; the loop repeats in case a request finished at its
                # very first token and freed its slot again.
                while self.queue and any(s is None for s in slots):
                    pending = []
                    for i in range(B):
                        if slots[i] is None and self.queue:
                            pending.append((i, self.queue.popleft()))
                    admit_ts = time.perf_counter()
                    by_len: dict[int, list] = {}
                    for i, r in pending:
                        self.lifecycle[r.uid].append(PREFILLING)
                        if self.prefix_cache is not None:
                            state, hit = self._try_prefix_admit(
                                state, slots, tok, pos, done, i, r, admit_ts)
                            if hit:
                                continue
                        by_len.setdefault(len(r.prompt), []).append((i, r))
                    for _, group in sorted(by_len.items()):
                        ids = [i for i, _ in group]
                        prompts = np.stack([r.prompt for _, r in group]
                                           ).astype(np.int32)
                        if self.prefix_cache is None:
                            state, first = eng.admit_slots(
                                state, ids, {"tokens": jnp.asarray(prompts)})
                        else:
                            # prefill-then-insert (bit-identical to
                            # admit_slots: same prefill program + donated
                            # masked insert) so the finalized rows are still
                            # in hand to snapshot into the store
                            from repro.core import cache as cache_lib
                            logits, rows = eng.prefill_rows(
                                {"tokens": jnp.asarray(prompts)})
                            state = cache_lib.insert_slots(state, ids, rows)
                            first = jnp.argmax(logits, axis=-1)
                            for j, (_, r) in enumerate(group):
                                self._capture_prefix(
                                    r, rows, j,
                                    int(np.asarray(first)[j]))
                        first = np.asarray(first)
                        for (i, r), f in zip(group, first):
                            self._activate(slots, tok, pos, done, i, r,
                                           int(f), admit_ts)
            else:
                # -- chunked admission: reserve free slots, then advance
                # prefill work under the stall bound (one chunk per segment
                # while anything decodes; run-to-admission when idle).
                reserved = {i for g in jobs for i, _ in g.assignments}
                state, new_groups = self._open_prefill_groups(
                    state, slots, tok, pos, done, reserved)
                jobs.extend(new_groups)
                live = sum(s is not None for s in slots)
                chunks_this_boundary = 0
                while jobs:
                    if live > 0 and chunks_this_boundary >= 1:
                        break
                    head = jobs[0]
                    if not head.job.finished:
                        head.job = eng.prefill_chunk_step(head.job)
                        chunks_this_boundary += 1
                    if head.job.finished:
                        ids = [i for i, _ in head.assignments]
                        if self.prefix_cache is not None:
                            state, first, rows = eng.finish_prefill_chunked(
                                state, head.job, ids, return_rows=True)
                            for j, (_, r) in enumerate(head.assignments):
                                self._capture_prefix(
                                    r, rows, j,
                                    int(np.asarray(first)[j]))
                        else:
                            state, first = eng.finish_prefill_chunked(
                                state, head.job, ids)
                        for (i, r), f in zip(head.assignments,
                                             np.asarray(first)):
                            self._activate(slots, tok, pos, done, i, r,
                                           int(f), head.admit_ts,
                                           prefix_hit=head.prefix_hit)
                        jobs.pop(0)
                        if live == 0:
                            # rows just went live — stop burning boundaries
                            # on prefill and let them decode
                            break
                self.prefill_boundary_trace.append(
                    {"live": live, "chunks": chunks_this_boundary})

            # -- reset every unoccupied slot (batched, one fused op; a
            # no-op at steady state when all slots are live). Re-resetting
            # idle slots each boundary matters: decode_segment still steps
            # them, so without it a dead row's occupancy would creep up to
            # the prune trigger during a long drain-out tail — this bounds
            # dead-row occupancy to segment_len. -------------------------
            to_reset = [i for i in range(B) if slots[i] is None]
            if to_reset:
                state = eng.release_slots(state, to_reset, pad_to=B)

            active = [i for i in range(B) if slots[i] is not None]
            self.occupancy_trace.append(len(active))
            if not active:
                if jobs or self.queue:
                    continue                 # admission still in flight
                break                        # queue drained, nothing live

            # -- one decode segment over the live batch --------------------
            state, seg, pos_j, done_j = eng.decode_segment(
                state, tok, pos, done, self.segment_len, eos_id=eos)
            seg = np.asarray(seg)
            pos, done = np.array(pos_j), np.array(done_j)
            tok = seg[:, -1].astype(np.int32)
            self._decode_steps += self.segment_len
            now = time.perf_counter()
            self.segment_gap_trace.append((min(live_pre, len(active)),
                                           now - t_seg))
            t_seg = now
            if self.track_occupancy:
                self.max_slot_tokens = max(
                    self.max_slot_tokens, int(eng.slot_lengths(state).max()))

            # -- harvest: retire slots that finished inside the segment ----
            for i in active:
                slot = slots[i]
                want = slot.req.max_new_tokens
                reason = None
                for t in seg[i]:
                    slot.tokens.append(int(t))
                    if eos is not None and t == eos:
                        reason = "eos"
                        break
                    if len(slot.tokens) >= want:
                        reason = "length"
                        break
                if reason is not None:
                    self._finish(slot, reason)
                    slots[i] = None
                    done[i] = True

        self.completed.sort(key=lambda c: c.uid)
        return self.completed

    # ---- lockstep baseline -----------------------------------------------

    def _take_batch(self) -> list[Request]:
        batch = []
        while self.queue and len(batch) < self.batch_slots:
            batch.append(self.queue.popleft())
        return batch

    def run_lockstep(self) -> list[Completion]:
        """Drain the queue run-to-completion (the pre-continuous baseline):
        each packed batch decodes ``max(max_new_tokens)`` steps (or until
        every row hits EOS), so short requests wait on the batch's longest.
        Returns completions (uid-ordered)."""
        while self.queue:
            batch = self._take_batch()
            t_batch = time.perf_counter()
            S = max(len(r.prompt) for r in batch)
            toks = np.full((len(batch), S), self.pad_token, np.int32)
            for i, r in enumerate(batch):
                toks[i, S - len(r.prompt):] = r.prompt  # right-aligned
            want = max(r.max_new_tokens for r in batch)
            res = self.engine.generate_scan({"tokens": jnp.asarray(toks)},
                                            want, eos_id=self.eos_id)
            t_done = time.perf_counter()
            # residency = batch start -> batch done, matching run()'s
            # admit->finish accounting so per-request tok/s is comparable
            resid = max(t_done - t_batch, 1e-9)
            for i, r in enumerate(batch):
                self.lifecycle[r.uid] += [PREFILLING, DECODING, FINISHED]
                n = r.max_new_tokens
                gl = int(res.gen_lens[i])       # EOS-truncated (inclusive)
                row = res.tokens[i, :min(n, gl)]
                reason = ("eos" if res.finished[i] and gl <= n
                          else "length")
                self.completed.append(Completion(
                    uid=r.uid, tokens=row, latency_steps=len(row),
                    finish_reason=reason,
                    queue_wait_s=t_batch - self._submit_ts[r.uid],
                    ttft_s=(t_batch - self._submit_ts[r.uid]
                            + res.prefill_seconds),
                    decode_steps=len(row) - 1,
                    tokens_per_second=len(row) / resid,
                    kv_format=res.kv_format,
                    cache_bytes=res.cache_bytes))
        self.completed.sort(key=lambda c: c.uid)
        return self.completed
