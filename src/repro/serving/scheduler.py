"""Request scheduler: continuous lockstep batching over fixed decode slots.

Requests queue up, get packed into a fixed-width batch (right-aligned padded
prompts so every row's last prompt token sits at the same position), decode
in lockstep, and finished rows are refilled from the queue between decode
segments. This is the serving shape of the paper's multi-batch experiments
(Tables 2–3: batch sizes 1..32 under memory pressure).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    latency_steps: int


class Scheduler:
    def __init__(self, engine: Engine, batch_slots: int, pad_token: int = 0,
                 segment_len: int = 32):
        self.engine = engine
        self.batch_slots = batch_slots
        self.pad_token = pad_token
        self.segment_len = segment_len
        self.queue: collections.deque[Request] = collections.deque()
        self.completed: list[Completion] = []

    def submit(self, reqs: Iterable[Request]) -> None:
        self.queue.extend(reqs)

    def _take_batch(self) -> list[Request]:
        batch = []
        while self.queue and len(batch) < self.batch_slots:
            batch.append(self.queue.popleft())
        return batch

    def run(self) -> list[Completion]:
        """Drain the queue; returns completions (uid-ordered)."""
        while self.queue:
            batch = self._take_batch()
            S = max(len(r.prompt) for r in batch)
            toks = np.full((len(batch), S), self.pad_token, np.int32)
            for i, r in enumerate(batch):
                toks[i, S - len(r.prompt):] = r.prompt  # right-aligned
            want = max(r.max_new_tokens for r in batch)
            res = self.engine.generate({"tokens": jnp.asarray(toks)}, want)
            for i, r in enumerate(batch):
                self.completed.append(Completion(
                    uid=r.uid,
                    tokens=res.tokens[i, :r.max_new_tokens],
                    latency_steps=r.max_new_tokens))
        self.completed.sort(key=lambda c: c.uid)
        return self.completed
