"""Content-hashed prefix reuse: a host-RAM KV store in front of admission.

Production reasoning traffic is dominated by shared prefixes (system
prompts, few-shot templates, multi-turn history), yet the scheduler and the
front door recompute every admission's prefill from token zero. This module
is the store that stops that:

* **Key** — a rolling blake2b chain over the token stream, sampled at
  *chunk-plan boundaries* (``engine.chunk_plan`` cumulative sums). Digest at
  boundary ``b`` is ``H(digest_{b-1} ‖ tokens[prev:b])``, seeded with the
  caller's **fingerprint** (policy config + ``kv_format`` + cache dtype +
  arch identity), so entries produced under one policy/format can never hit
  a lookup under another — incompatible caches differ at the *seed*, not
  just at a checked field. Because any boundary that is a multiple of the
  pow2 chunk budget decomposes as ``[p]*k`` for every prompt, a digest at
  such a boundary is shared by all prompts with the same first ``b`` tokens:
  partial hits probe multiples of ``p``; the full-length digest also covers
  the remainder boundaries.

* **Value** — the full per-request slot snapshot captured through the PR 5
  ``cache.extract_slots`` path right after prefill finalize: KV payload
  (bf16 or int8 + dequant scales), RASR scores, per-layer budget /
  ``evict_at`` / sparsity state, plus the greedy first token. A Lethe entry
  therefore stores *compressed* KV — a hit admits at reduced bytes, and the
  evolving score state rides along instead of being rebuilt on hit
  (LazyEviction's lagged-observation argument).

* **Tier** — host RAM with a bytes cap. Eviction is TTL-then-LRU: expiry
  first (TTL grows with the entry's hit count — the LMCache
  ``compute_ttl`` heuristic: ``base_ttl * (1 + α·ln(1 + hits))`` clamped
  to ``[min_ttl, max_ttl]`` — so hot prefixes outlive cold ones), then
  least-recently-used until the new entry fits.

On a **full** hit the stored rows are ``insert_slots``-ed instead of
running prefill — bit-identical to recomputation (the snapshot round-trip
is bit-exact and the stored rows *are* the finalize output). On a
**partial** hit, chunked prefill resumes from the restored state for the
suffix only (``Engine.start_prefill_resumed``). DESIGN.md §Prefix-reuse
covers the compressed-hit trade; ``benchmarks/prefix_reuse.py`` measures
it under Zipfian prefix popularity.
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np


def prefix_fingerprint(policy, cache_dtype=None, arch: str = "",
                       mesh: str = "") -> bytes:
    """Compatibility fingerprint for stored entries: every knob that changes
    the *bytes* a prefill produces. Two engines whose fingerprints differ
    must never exchange entries — the fingerprint seeds the hash chain, so a
    mismatch produces disjoint key spaces rather than a checked failure.

    ``mesh`` is the serving mesh's topology token
    (``ServingMesh.topology_token()``: axis names/sizes + device count, ""
    for single-device) — snapshots captured under one sharding must never
    hit a lookup under another: the snapshot gather and the insert scatter
    are layout-exact only within one placement."""
    blob = "|".join([repr(sorted(vars(policy).items())),
                     str(cache_dtype), str(arch), str(mesh)])
    return hashlib.blake2b(blob.encode(), digest_size=16).digest()


def chain_digests(fingerprint: bytes, tokens: np.ndarray,
                  boundaries: tuple[int, ...]) -> list[tuple[int, bytes]]:
    """Rolling hash chain over ``tokens`` sampled at ``boundaries``
    (ascending cumulative chunk-plan sums). Returns [(boundary, digest)].
    The chain make digests prefix-consistent: two prompts sharing their
    first ``b`` tokens (and the decomposition up to ``b``) share the digest
    at ``b``."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32).reshape(-1))
    out = []
    digest = fingerprint
    prev = 0
    for b in boundaries:
        h = hashlib.blake2b(digest, digest_size=16)
        h.update(toks[prev:b].tobytes())
        digest = h.digest()
        out.append((b, digest))
        prev = b
    return out


def _rows_nbytes(rows) -> int:
    """Physical host bytes of a snapshot pytree (numpy leaves)."""
    import jax
    return sum(leaf.nbytes for leaf in jax.tree.leaves(rows))


@dataclass
class PrefixCacheConfig:
    max_bytes: int = 1 << 30        # host-tier cap over all entries
    block_size: int = 32            # hash-boundary granularity: the prefill
    #                                 chunk budget (boundaries = cumulative
    #                                 chunk_plan sums -> partial hits land
    #                                 on multiples of the pow2 chunk)
    base_ttl_s: float = 600.0       # TTL of a never-hit entry
    min_ttl_s: float = 30.0
    max_ttl_s: float = 6 * 3600.0
    ttl_alpha: float = 0.5          # hit-count TTL boost (LMCache heuristic)
    min_tokens: int = 2             # don't store trivial prompts
    capture: bool = True            # record new entries on miss


@dataclass
class PrefixEntry:
    """One stored prefix: the full slot snapshot plus reuse bookkeeping."""
    digest: bytes
    prefix_len: int
    rows: object                    # host numpy pytree, batch axis 1
    first_token: int                # greedy token the prefill emitted
    nbytes: int
    created: float
    last_access: float
    access_count: int = 0
    ttl_s: float = 0.0

    def expired(self, now: float) -> bool:
        return now - self.last_access > self.ttl_s


@dataclass
class PrefixHit:
    entry: PrefixEntry
    prefix_len: int                 # matched tokens (== entry.prefix_len)
    full: bool                      # matched the whole prompt


class PrefixCache:
    """Bytes-capped host-RAM prefix store with TTL/LRU eviction.

    Pure host-side bookkeeping — no jax in the hot path, injectable clock
    (tests drive expiry deterministically). One store may be shared by many
    engines; the per-call ``fingerprint`` keeps their entries disjoint.
    """

    def __init__(self, cfg: PrefixCacheConfig | None = None, *,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or PrefixCacheConfig()
        self.clock = clock
        self._entries: dict[bytes, PrefixEntry] = {}
        self.bytes_used = 0
        # counters (cumulative over the store's lifetime)
        self.n_lookups = 0
        self.n_full_hits = 0
        self.n_partial_hits = 0
        self.n_misses = 0
        self.n_inserts = 0
        self.n_evictions_ttl = 0
        self.n_evictions_lru = 0
        self.n_too_large = 0
        self.n_load_skipped = 0

    # ---- hashing ----------------------------------------------------------

    def _boundaries(self, n: int) -> tuple[int, ...]:
        from repro.serving.engine import chunk_plan
        return tuple(int(b) for b in
                     np.cumsum(chunk_plan(n, self.cfg.block_size)))

    def compute_ttl(self, entry: PrefixEntry) -> float:
        """LMCache-style hit-rate-driven TTL: hot prefixes live longer."""
        c = self.cfg
        ttl = c.base_ttl_s * (1.0 + c.ttl_alpha
                              * np.log1p(entry.access_count))
        return float(np.clip(ttl, c.min_ttl_s, c.max_ttl_s))

    # ---- store ops --------------------------------------------------------

    def lookup(self, fingerprint: bytes, tokens: np.ndarray
               ) -> PrefixHit | None:
        """Longest-prefix probe of the chunk-plan boundaries (full length
        first). A hit refreshes recency and extends the entry's TTL."""
        self.n_lookups += 1
        toks = np.asarray(tokens, np.int32).reshape(-1)
        n = len(toks)
        if n == 0:
            self.n_misses += 1
            return None
        now = self.clock()
        chain = chain_digests(fingerprint, toks, self._boundaries(n))
        for b, digest in reversed(chain):
            e = self._entries.get(digest)
            if e is None:
                continue
            if e.expired(now):
                self._evict(digest, ttl=True)
                continue
            e.access_count += 1
            e.last_access = now
            e.ttl_s = self.compute_ttl(e)
            full = b == n
            if full:
                self.n_full_hits += 1
            else:
                self.n_partial_hits += 1
            return PrefixHit(entry=e, prefix_len=b, full=full)
        self.n_misses += 1
        return None

    def insert(self, fingerprint: bytes, tokens: np.ndarray, rows,
               first_token: int) -> bool:
        """Store the snapshot of a fully prefilled prompt, evicting
        (expired first, then LRU) until it fits. Returns False when the
        prompt is trivial, already stored, or larger than the whole tier."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        n = len(toks)
        if not self.cfg.capture or n < self.cfg.min_tokens:
            return False
        digest = chain_digests(fingerprint, toks, self._boundaries(n))[-1][1]
        if digest in self._entries:
            return False
        nbytes = _rows_nbytes(rows)
        if nbytes > self.cfg.max_bytes:
            self.n_too_large += 1
            return False
        self.sweep()
        while self.bytes_used + nbytes > self.cfg.max_bytes:
            lru = min(self._entries.values(), key=lambda e: e.last_access)
            self._evict(lru.digest, ttl=False)
        now = self.clock()
        e = PrefixEntry(digest=digest, prefix_len=n, rows=rows,
                        first_token=int(first_token), nbytes=nbytes,
                        created=now, last_access=now)
        e.ttl_s = self.compute_ttl(e)
        self._entries[digest] = e
        self.bytes_used += nbytes
        self.n_inserts += 1
        return True

    def _evict(self, digest: bytes, *, ttl: bool) -> None:
        e = self._entries.pop(digest)
        self.bytes_used -= e.nbytes
        if ttl:
            self.n_evictions_ttl += 1
        else:
            self.n_evictions_lru += 1

    def sweep(self) -> int:
        """Drop every TTL-expired entry; returns how many were dropped."""
        now = self.clock()
        dead = [d for d, e in self._entries.items() if e.expired(now)]
        for d in dead:
            self._evict(d, ttl=True)
        return len(dead)

    # ---- disk persistence (checkpoint/ckpt.py bit-exact pack) -------------

    def save(self, path: str) -> int:
        """Persist every entry to ``<path>.npz`` + ``<path>.meta.json``
        through the same bit-exact pack the durability checkpoints use —
        a warm prefix tier survives a serving restart instead of being
        rebuilt one cold prefill at a time. Returns entries written."""
        from repro.checkpoint import ckpt
        arrays: dict = {}
        entries_meta = []
        for i, e in enumerate(self._entries.values()):
            a, meta = ckpt.pack_bitexact(e.rows, prefix=f"e{i}/")
            arrays.update(a)
            entries_meta.append({
                "digest": e.digest.hex(), "prefix_len": e.prefix_len,
                "first_token": e.first_token, "nbytes": e.nbytes,
                "access_count": e.access_count, "rows_meta": meta,
            })
        np.savez(path + ".npz", **arrays)
        with open(path + ".meta.json", "w") as f:
            json.dump({"entries": entries_meta}, f)
        return len(entries_meta)

    def load(self, path: str, donor_row) -> int:
        """Merge persisted entries back into the store. ``donor_row`` is a
        single-row ``extract_slots`` of a fresh decode state under the
        loading engine's config (the structure donor for the bit-exact
        unpack); entries packed under an incompatible leaf layout (e.g. an
        int8 store loaded by a bf16 engine, whose rows lack scale leaves)
        are skipped, not coerced — their fingerprints could never hit this
        engine's lookups anyway. Recency restarts at load time (host
        clocks do not survive a restart); hit counts, and therefore TTLs,
        carry over. Returns entries loaded."""
        from repro.checkpoint import ckpt
        with open(path + ".meta.json") as f:
            meta = json.load(f)
        with np.load(path + ".npz") as data:
            arrays = dict(data)
        now = self.clock()
        loaded = 0
        donor_keys = {k for k, _ in ckpt._flatten_with_paths(donor_row)}
        for em in meta["entries"]:
            digest = bytes.fromhex(em["digest"])
            if digest in self._entries:
                continue
            rm = em["rows_meta"]
            keys = {k[len(rm.get("prefix", "")):] for k in rm["keys"]}
            if keys != donor_keys:      # strict: unpack would silently
                self.n_load_skipped += 1  # drop donor-absent leaves
                continue
            try:
                rows = ckpt.unpack_bitexact(arrays, rm, donor_row)
            except (KeyError, TypeError, ValueError):
                self.n_load_skipped += 1
                continue
            e = PrefixEntry(digest=digest, prefix_len=em["prefix_len"],
                            rows=rows, first_token=em["first_token"],
                            nbytes=em["nbytes"], created=now,
                            last_access=now,
                            access_count=em["access_count"])
            e.ttl_s = self.compute_ttl(e)
            self._entries[digest] = e
            self.bytes_used += e.nbytes
            loaded += 1
        while self.bytes_used > self.cfg.max_bytes and self._entries:
            lru = min(self._entries.values(), key=lambda e: e.last_access)
            self._evict(lru.digest, ttl=False)
        return loaded

    # ---- telemetry --------------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    def hit_rate(self) -> float:
        hits = self.n_full_hits + self.n_partial_hits
        return hits / max(self.n_lookups, 1)

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "bytes_used": self.bytes_used,
            "max_bytes": self.cfg.max_bytes,
            "lookups": self.n_lookups,
            "full_hits": self.n_full_hits,
            "partial_hits": self.n_partial_hits,
            "misses": self.n_misses,
            "hit_rate": self.hit_rate(),
            "inserts": self.n_inserts,
            "evictions_ttl": self.n_evictions_ttl,
            "evictions_lru": self.n_evictions_lru,
            "too_large": self.n_too_large,
            "load_skipped": self.n_load_skipped,
        }
