"""Serving engine: lockstep batched prefill + decode with Lethe cache
management.

Two decode drivers:
  * ``generate``      — Python-stepped loop (per-step stats: cache occupancy,
                        prune activity, memory) used by benchmarks/examples.
  * ``generate_scan`` — whole decode under one ``lax.scan`` (single XLA
                        program; the throughput-measurement path and the
                        shape that ``serve_step`` dry-runs lower).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core.policy import PolicyConfig
from repro.models.api import ModelAPI


def _sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def _cache_stats(state) -> dict:
    """Occupancy/memory stats for any model state containing a KVCache."""
    caches = [x for x in jax.tree.leaves(
        state, is_leaf=lambda t: isinstance(t, cache_lib.KVCache))
        if isinstance(x, cache_lib.KVCache)]
    if not caches:
        leaves = jax.tree.leaves(state)
        return {"cache_bytes": sum(x.size * x.dtype.itemsize
                                   for x in leaves),
                "live_tokens": 0, "capacity_tokens": 0}
    total_bytes = sum(c.memory_bytes() for c in caches)
    live = sum(int(np.asarray(jnp.sum(c.length))) for c in caches)
    cap = sum(c.k.shape[0] * c.k.shape[1] * c.capacity for c in caches)
    return {"cache_bytes": total_bytes, "live_tokens": live,
            "capacity_tokens": cap}


@dataclass
class GenerationResult:
    tokens: np.ndarray                 # [B, N]
    prefill_seconds: float
    decode_seconds: float
    tokens_per_second: float
    steps: int
    cache_bytes: int
    live_token_trace: list = field(default_factory=list)
    logits_trace: Any = None


class Engine:
    """Batched serving over one model + one policy."""

    def __init__(self, model: ModelAPI, params, policy: PolicyConfig,
                 cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.policy = policy
        self.cache_dtype = cache_dtype

    def prefill(self, batch: dict):
        return self.model.prefill(self.params, batch, self.policy,
                                  cache_dtype=self.cache_dtype)

    def generate(self, batch: dict, max_new_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0,
                 trace_live: bool = False,
                 collect_logits: bool = False) -> GenerationResult:
        B, S = batch["tokens"].shape
        t0 = time.perf_counter()
        logits, state = self.prefill(batch)
        logits.block_until_ready()
        t1 = time.perf_counter()

        key = jax.random.PRNGKey(seed)
        tok = _sample(logits, key, temperature)
        s_img = (batch.get("img_embeds").shape[1]
                 if batch.get("img_embeds") is not None else 0)
        out = [np.asarray(tok)]
        logit_rows = [np.asarray(logits)] if collect_logits else None
        live_trace = []
        for t in range(max_new_tokens - 1):
            cur = jnp.asarray(S + s_img + t, jnp.int32)
            key, sub = jax.random.split(key)
            logits, state = self.model.decode_step(
                self.params, state, tok, cur, self.policy)
            tok = _sample(logits, sub, temperature)
            out.append(np.asarray(tok))
            if collect_logits:
                logit_rows.append(np.asarray(logits))
            if trace_live:
                live_trace.append(_cache_stats(state)["live_tokens"])
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        stats = _cache_stats(state)
        n = max_new_tokens
        return GenerationResult(
            tokens=np.stack(out, axis=1),
            prefill_seconds=t1 - t0,
            decode_seconds=t2 - t1,
            tokens_per_second=B * n / max(t2 - t1, 1e-9),
            steps=n,
            cache_bytes=stats["cache_bytes"],
            live_token_trace=live_trace,
            logits_trace=(np.stack(logit_rows, axis=1)
                          if collect_logits else None),
        )

    def generate_scan(self, batch: dict, max_new_tokens: int, *,
                      temperature: float = 0.0, seed: int = 0
                      ) -> GenerationResult:
        """Whole decode inside one jitted lax.scan (throughput path)."""
        B, S = batch["tokens"].shape
        s_img = (batch.get("img_embeds").shape[1]
                 if batch.get("img_embeds") is not None else 0)
        t0 = time.perf_counter()
        logits, state = self.prefill(batch)
        logits.block_until_ready()
        t1 = time.perf_counter()

        model, params, policy = self.model, self.params, self.policy

        def step(carry, t):
            state, tok, key = carry
            key, sub = jax.random.split(key)
            logits, state = model.module.decode_step(
                params, state, tok, S + s_img + t, model.cfg, policy)
            nxt = _sample(logits, sub, temperature)
            return (state, nxt, key), nxt

        tok0 = _sample(logits, jax.random.PRNGKey(seed), temperature)

        # Donate the prefill state into the scan: the whole decode loop then
        # runs against one in-place cache allocation (the per-step
        # decode_step donation covers the Python-stepped `generate` driver).
        @functools.partial(jax.jit, donate_argnums=(0,))
        def run(state, tok0, key):
            (state, _, _), toks = jax.lax.scan(
                step, (state, tok0, key),
                jnp.arange(max_new_tokens - 1, dtype=jnp.int32))
            return state, toks

        state, toks = run(state, tok0, jax.random.PRNGKey(seed + 1))
        jax.block_until_ready(toks)
        t2 = time.perf_counter()
        tokens = np.concatenate(
            [np.asarray(tok0)[:, None], np.asarray(toks).T], axis=1)
        stats = _cache_stats(state)
        return GenerationResult(
            tokens=tokens, prefill_seconds=t1 - t0, decode_seconds=t2 - t1,
            tokens_per_second=B * max_new_tokens / max(t2 - t1, 1e-9),
            steps=max_new_tokens, cache_bytes=stats["cache_bytes"])
