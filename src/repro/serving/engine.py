"""Serving engine: batched prefill + decode with Lethe cache management.

Two whole-request decode drivers (both EOS-aware — pass ``eos_id`` and a
row freezes once it emits EOS; decode stops early when every row is done):
  * ``generate``      — Python-stepped loop (per-step stats: cache occupancy,
                        prune activity, memory) used by benchmarks/examples.
  * ``generate_scan`` — whole decode under one XLA program (``lax.scan``,
                        or an early-exiting ``lax.while_loop`` when an EOS
                        is set; the throughput-measurement path and the
                        shape that ``serve_step`` dry-runs lower).

Plus the slot-scoped primitives the continuous-batching scheduler composes
(per-request lifecycles over a fixed-width live batch):
  * ``new_decode_state`` — empty B-slot live state.
  * ``admit_slot``       — B=1 prefill of one request, inserted into a slot
                           of the live state (donated masked select).
  * ``release_slot``     — retire a finished slot back to empty.
  * ``decode_segment``   — ``segment_len`` greedy steps with *per-row*
                           positions and done-flags under one ``lax.scan``.

And the chunked-prefill admission path (DESIGN.md §Prefill) that turns a
prompt into a stream of schedulable work units so admission never stalls
live decodes:
  * ``start_prefill_chunked``  — open a ``PrefillJob`` (pow2 ``chunk_plan``,
                                 working-buffer carry; prompts longer than
                                 capacity stream through prefill-phase
                                 compression).
  * ``prefill_chunk_step``     — advance one chunk (donated carry).
  * ``finish_prefill_chunked`` — finalize + donated insert, first tokens.
  * ``admit_slots_chunked``    — one-shot form, differentially equal to
                                 ``admit_slots`` for fits-capacity prompts.
"""
from __future__ import annotations

import functools
import os
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core.policy import PolicyConfig
from repro.models.api import ModelAPI
from repro.serving.meshing import ServingMesh, mesh_context


# Fault classification codes in ``decode_segment_guarded``'s ``bad_kind``
# output (0 = healthy row): the device-side half of the front door's
# ``Completion.failure_detail`` taxonomy.
BAD_NAN = 1        # non-finite logits (real or chaos-injected)
BAD_FAULT = 2      # flagged per-row kernel fault


def _meshed(fn):
    """Run an engine entry point under the engine's mesh context (no-op
    for a no-mesh engine): inside ``with mesh:`` the shard_map decode
    kernel dispatch and the ``shard_hints`` constraints bind, and the jit
    trace cache keys on the ambient mesh so mesh/no-mesh engines never
    share a traced program."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with mesh_context(self.mesh):
            return fn(self, *args, **kwargs)
    return wrapper


def _sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def _cache_stats(state) -> dict:
    """Occupancy/memory stats for any model state containing a KVCache.

    ``cache_bytes`` is physical (K/V payloads + int8 dequant scales + RASR
    scores + metadata); ``cache_bytes_breakdown`` itemises it per leaf
    group so benchmark JSONs record real bytes, not just slot capacity.
    """
    caches = [x for x in jax.tree.leaves(
        state, is_leaf=lambda t: isinstance(t, cache_lib.KVCache))
        if isinstance(x, cache_lib.KVCache)]
    if not caches:
        leaves = jax.tree.leaves(state)
        return {"cache_bytes": sum(x.size * x.dtype.itemsize
                                   for x in leaves),
                "cache_bytes_breakdown": {}, "kv_format": "none",
                "live_tokens": 0, "capacity_tokens": 0}
    breakdown: dict[str, int] = {}
    for c in caches:
        for name, b in c.memory_breakdown().items():
            breakdown[name] = breakdown.get(name, 0) + b
    total_bytes = sum(breakdown.values())
    live = sum(int(np.asarray(jnp.sum(c.length))) for c in caches)
    cap = sum(c.k.shape[0] * c.k.shape[1] * c.capacity for c in caches)
    return {"cache_bytes": total_bytes,
            "cache_bytes_breakdown": breakdown,
            "kv_format": "int8" if caches[0].quantized else "bf16",
            "live_tokens": live, "capacity_tokens": cap}


@dataclass
class GenerationResult:
    tokens: np.ndarray                 # [B, N] (rows frozen at eos_id once done)
    prefill_seconds: float
    decode_seconds: float
    tokens_per_second: float
    steps: int                         # decode steps actually executed (≤ N)
    cache_bytes: int                   # physical (payload+scales+score+meta)
    live_token_trace: list = field(default_factory=list)
    logits_trace: Any = None
    gen_lens: np.ndarray | None = None  # [B] tokens up to & incl. EOS
    finished: np.ndarray | None = None  # [B] bool — row emitted EOS
    cache_bytes_breakdown: dict = field(default_factory=dict)
    kv_format: str = "bf16"


def _gen_lens(tokens: np.ndarray, eos_id: int | None) -> tuple[np.ndarray,
                                                               np.ndarray]:
    """Per-row generated length (truncated after the first EOS, inclusive)
    and finished flags. tokens [B, N]."""
    B, N = tokens.shape
    if eos_id is None:
        return np.full((B,), N, np.int32), np.zeros((B,), bool)
    hit = tokens == eos_id
    finished = hit.any(axis=1)
    first = np.where(finished, hit.argmax(axis=1) + 1, N)
    return first.astype(np.int32), finished


def chunk_plan(s_total: int, chunk_budget: int) -> tuple[int, ...]:
    """Power-of-two chunk decomposition of a prompt length.

    The plan is ``P = 2^⌊log2(budget)⌋`` repeated, then the binary
    decomposition of the remainder (descending) — so across *every* prompt
    length the set of distinct chunk shapes is {1, 2, 4, …, P}: a refill
    wave over arbitrarily mixed lengths compiles O(log chunk_budget) chunk
    programs instead of one prefill program per distinct length (the chunk
    offset is traced, not baked into the program).
    """
    assert s_total > 0 and chunk_budget > 0
    p = 1
    while p * 2 <= chunk_budget:
        p *= 2
    plan = [p] * (s_total // p)
    rem = s_total % p
    for b in reversed(range(rem.bit_length())):
        if rem & (1 << b):
            plan.append(1 << b)
    return tuple(plan)


@dataclass
class PrefillJob:
    """Host-side handle for an in-flight chunked prefill (one admission
    group of equal-length prompts). Advanced one chunk at a time by
    ``Engine.prefill_chunk_step``; the device carry is donated through each
    step."""
    carry: Any
    batch: dict                  # (possibly row-padded) admission batch
    plan: tuple[int, ...]
    s_total: int
    compress: bool
    n_real: int                  # real request rows (before row padding)
    next_chunk: int = 0
    # prefix-reuse resume: the carry was seeded from restored rows and
    # ``batch`` holds only the suffix — the plan covers suffix tokens and
    # the working buffer is NOT contiguous-from-zero (no flash offset).
    resumed: bool = False

    @property
    def finished(self) -> bool:
        return self.next_chunk >= len(self.plan)

    @property
    def chunks_total(self) -> int:
        return len(self.plan)


class Engine:
    """Batched serving over one model + one policy."""

    def __init__(self, model: ModelAPI, params, policy: PolicyConfig,
                 cache_dtype=jnp.float32,
                 mesh: "ServingMesh | str | tuple[int, int] | None" = None):
        from repro.models.api import check_kv_format
        check_kv_format(model.cfg, policy)   # fail at build, not inside jit
        self.model = model
        # Mesh-sharded serving: ``mesh`` (a ServingMesh, or "dp,tp" / a
        # (dp, tp) tuple for convenience) places the params once here and
        # wraps every entry point in the mesh context; None keeps the
        # single-device path untouched.
        if mesh is not None and not isinstance(mesh, ServingMesh):
            mesh = ServingMesh.build(mesh)
        self.mesh = mesh
        if mesh is not None:
            params = mesh.shard_params(params, model.cfg)
        self.params = params
        self.policy = policy
        self.cache_dtype = cache_dtype
        self._segment_cache: dict = {}
        self._scan_cache: dict = {}

    @_meshed
    def prefill(self, batch: dict):
        return self.model.prefill(self.params, batch, self.policy,
                                  cache_dtype=self.cache_dtype)

    @_meshed
    def generate(self, batch: dict, max_new_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0,
                 eos_id: int | None = None,
                 trace_live: bool = False,
                 collect_logits: bool = False) -> GenerationResult:
        B, S = batch["tokens"].shape
        t0 = time.perf_counter()
        logits, state = self.prefill(batch)
        logits.block_until_ready()
        t1 = time.perf_counter()

        key = jax.random.PRNGKey(seed)
        tok = _sample(logits, key, temperature)
        done = ((tok == eos_id) if eos_id is not None
                else jnp.zeros((B,), bool))
        s_img = (batch.get("img_embeds").shape[1]
                 if batch.get("img_embeds") is not None else 0)
        out = [np.asarray(tok)]
        logit_rows = [np.asarray(logits)] if collect_logits else None
        live_trace = []
        for t in range(max_new_tokens - 1):
            if eos_id is not None and bool(jnp.all(done)):
                break   # EOS-aware early termination
            cur = jnp.asarray(S + s_img + t, jnp.int32)
            key, sub = jax.random.split(key)
            logits, state = self.model.decode_step(
                self.params, state, tok, cur, self.policy)
            tok = _sample(logits, sub, temperature)
            if eos_id is not None:
                tok = jnp.where(done, eos_id, tok)   # freeze finished rows
                done = done | (tok == eos_id)
            out.append(np.asarray(tok))
            if collect_logits:
                logit_rows.append(np.asarray(logits))
            if trace_live:
                live_trace.append(_cache_stats(state)["live_tokens"])
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        stats = _cache_stats(state)
        steps = len(out)
        tokens = np.stack(out, axis=1)
        if steps < max_new_tokens:   # pad early-terminated decode to full N
            pad = np.full((B, max_new_tokens - steps), eos_id, np.int32)
            tokens = np.concatenate([tokens, pad], axis=1)
        lens, finished = _gen_lens(tokens, eos_id)
        return GenerationResult(
            tokens=tokens,
            prefill_seconds=t1 - t0,
            decode_seconds=t2 - t1,
            tokens_per_second=B * steps / max(t2 - t1, 1e-9),
            steps=steps,
            cache_bytes=stats["cache_bytes"],
            live_token_trace=live_trace,
            logits_trace=(np.stack(logit_rows, axis=1)
                          if collect_logits else None),
            gen_lens=lens, finished=finished,
            cache_bytes_breakdown=stats["cache_bytes_breakdown"],
            kv_format=stats["kv_format"],
        )

    @_meshed
    def generate_scan(self, batch: dict, max_new_tokens: int, *,
                      temperature: float = 0.0, seed: int = 0,
                      eos_id: int | None = None) -> GenerationResult:
        """Whole decode inside one XLA program (throughput path).

        Without an EOS this is the unchanged ``lax.scan``. With ``eos_id``
        the decode becomes a ``lax.while_loop`` that terminates as soon as
        every row has emitted EOS — same freeze semantics as ``generate``,
        so the two drivers stay token-identical under greedy decoding.
        """
        B, S = batch["tokens"].shape
        s_img = (batch.get("img_embeds").shape[1]
                 if batch.get("img_embeds") is not None else 0)
        t0 = time.perf_counter()
        logits, state = self.prefill(batch)
        logits.block_until_ready()
        t1 = time.perf_counter()

        tok0 = _sample(logits, jax.random.PRNGKey(seed), temperature)
        run = self._scan_run(B, S, s_img, max_new_tokens, temperature, eos_id)
        state, toks, t_done = run(state, tok0, jax.random.PRNGKey(seed + 1))
        jax.block_until_ready(toks)
        t2 = time.perf_counter()
        tokens = np.concatenate(
            [np.asarray(tok0)[:, None], np.asarray(toks).T], axis=1)
        steps = int(t_done) + 1
        stats = _cache_stats(state)
        lens, finished = _gen_lens(tokens, eos_id)
        return GenerationResult(
            tokens=tokens, prefill_seconds=t1 - t0, decode_seconds=t2 - t1,
            tokens_per_second=B * steps / max(t2 - t1, 1e-9),
            steps=steps, cache_bytes=stats["cache_bytes"],
            gen_lens=lens, finished=finished,
            cache_bytes_breakdown=stats["cache_bytes_breakdown"],
            kv_format=stats["kv_format"])

    def _scan_run(self, B: int, S: int, s_img: int, max_new_tokens: int,
                  temperature: float, eos_id: int | None):
        """Build (or fetch) the jitted whole-decode program for one serving
        shape. Cached per engine so repeated ``generate_scan`` calls with
        the same shape — the scheduler's lockstep mode, throughput
        benchmarks — pay tracing + compilation once."""
        cache_key = (B, S, s_img, max_new_tokens, temperature, eos_id)
        cached = self._scan_cache.get(cache_key)
        if cached is not None:
            return cached

        model, params, policy = self.model, self.params, self.policy
        N1 = max_new_tokens - 1

        def one_step(state, tok, key, t):
            key, sub = jax.random.split(key)
            logits, state = model.module.decode_step(
                params, state, tok, S + s_img + t, model.cfg, policy)
            return state, _sample(logits, sub, temperature), key

        # Donate the prefill state into the loop: the whole decode then runs
        # against one in-place cache allocation (the per-step decode_step
        # donation covers the Python-stepped `generate` driver).
        if eos_id is None:
            @functools.partial(jax.jit, donate_argnums=(0,))
            def run(state, tok0, key):
                def step(carry, t):
                    state, tok, key = carry
                    state, nxt, key = one_step(state, tok, key, t)
                    return (state, nxt, key), nxt
                (state, _, _), toks = jax.lax.scan(
                    step, (state, tok0, key),
                    jnp.arange(N1, dtype=jnp.int32))
                return state, toks, jnp.asarray(N1, jnp.int32)
        else:
            @functools.partial(jax.jit, donate_argnums=(0,))
            def run(state, tok0, key):
                out0 = jnp.full((N1, B), eos_id, jnp.int32)
                done0 = tok0 == eos_id

                def cond(c):
                    _, _, _, t, done, _ = c
                    return (t < N1) & ~jnp.all(done)

                def body(c):
                    state, tok, key, t, done, out = c
                    state, nxt, key = one_step(state, tok, key, t)
                    nxt = jnp.where(done, eos_id, nxt)
                    done = done | (nxt == eos_id)
                    return (state, nxt, key, t + 1, done,
                            out.at[t].set(nxt))

                state, _, _, t, _, out = jax.lax.while_loop(
                    cond, body, (state, tok0, key,
                                 jnp.asarray(0, jnp.int32), done0, out0))
                return state, out, t

        self._scan_cache[cache_key] = run
        return run

    # ---- continuous-batching slot primitives ------------------------------
    # A live decode state is a fixed-width batch of B slots; requests are
    # admitted into / retired from individual slots between decode segments.
    # All three mutators are jitted with the live state donated, so slot
    # turnover is an in-place masked select over the standing allocation.

    @_meshed
    def new_decode_state(self, batch_slots: int, **kw):
        """Empty live state with ``batch_slots`` decode slots (placed on
        the serving mesh when one is bound: kv-heads on ``model``, slots on
        ``data``, capacity axis C shard-local)."""
        state = self.model.init_decode_state(
            self.policy, batch_slots, dtype=self.cache_dtype, **kw)
        if self.mesh is not None:
            state = self.mesh.shard_state(state, self.model.cfg,
                                          batch_slots)
        return state

    @_meshed
    def admit_slots(self, state, slot_ids, batch: dict):
        """Admit a group of same-length requests (``batch["tokens"]`` is
        [k, S], row j destined for live slot ``slot_ids[j]``) in one
        prefill + one donated insert (``ModelAPI.prefill_into_slot``).
        Each row goes through the full per-request policy machinery (RASR
        init, spatial budgets, forced prune round) — identical to a solo
        prefill, since every statistic is per-row.
        Returns (state', greedy first tokens [k])."""
        logits, state = self.model.prefill_into_slot(
            self.params, batch, self.policy, state, slot_ids,
            cache_dtype=self.cache_dtype)
        return state, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    @_meshed
    def admit_slot(self, state, slot: int, batch: dict):
        """Admit one request (``batch`` is a B=1 prompt) into slot ``slot``
        of the live state: solo prefill through the full policy machinery,
        then a donated insert. Returns (state', last-token logits [V])."""
        logits, state = self.model.prefill_into_slot(
            self.params, batch, self.policy, state, [slot],
            cache_dtype=self.cache_dtype)
        return state, logits[0]

    # ---- chunked prefill (stall-free admission; DESIGN.md §Prefill) -------

    @_meshed
    def start_prefill_chunked(self, batch: dict, *, chunk_size: int,
                              pad_rows_to: int | None = None) -> PrefillJob:
        """Open a chunked prefill for one group of equal-length requests.

        ``pad_rows_to`` right-pads the batch with dummy rows so every
        admission group shares one program per chunk shape regardless of
        group size (dummy rows are discarded at insert — their slot id is
        -1). Prompts longer than capacity stream through prefill-phase
        compression; a policy that cannot evict (FullKV) rejects them here.
        """
        s_total = self.model.total_prompt_len(batch)
        plan = chunk_plan(s_total, chunk_size)
        # Admission decision before any device work (the audio family's
        # init runs its whole encoder); raises for an over-capacity prompt
        # the policy cannot evict.
        compress = self.model.chunked_compress(self.policy, s_total)
        n_real = batch["tokens"].shape[0]
        if pad_rows_to is not None and n_real < pad_rows_to:
            pad = pad_rows_to - n_real

            def pad_rows(x):
                return jnp.concatenate(
                    [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
            batch = {k: (pad_rows(jnp.asarray(v)) if v is not None else v)
                     for k, v in batch.items()}
        carry = self.model.prefill_chunk_init(
            self.params, batch, self.policy, chunk_max=max(plan),
            cache_dtype=self.cache_dtype)
        if "buf" not in carry:
            compress = False     # recurrence-only family: O(1) state
        return PrefillJob(carry=carry, batch=batch, plan=plan,
                          s_total=s_total, compress=compress,
                          n_real=n_real)

    @_meshed
    def prefill_chunk_step(self, job: PrefillJob) -> PrefillJob:
        """Advance one chunk — the schedulable unit of prefill work. The
        carry is donated: each step mutates the standing working buffers.

        REPRO_CHUNK_FLASH=1 passes the chunk's *static* offset while the
        buffer is still contiguous, dispatching the Pallas flash kernel's
        ``q_offset`` path on TPU (one program per chunk offset — trades
        retraces for kernel throughput; windowed layer scans fall back to
        the slotted oracle inside ``ops.chunk_attention``). The default
        keeps the offset traced: O(log chunk) programs per refill wave.
        """
        assert not job.finished
        n = job.plan[job.next_chunk]
        done = sum(job.plan[:job.next_chunk])
        chunk = (None if self.model.cfg.family == "vlm"
                 else jnp.asarray(job.batch["tokens"][:, done:done + n]))
        offset = None
        if (os.environ.get("REPRO_CHUNK_FLASH", "0") == "1"
                and not job.resumed
                and done + n <= self.policy.capacity):
            offset = done        # contiguous: no compression has run yet
        job.carry = self.model.prefill_chunk(
            self.params, job.carry, chunk, self.policy, n=n,
            compress=job.compress, contiguous_offset=offset)
        job.next_chunk += 1
        return job

    @_meshed
    def finish_prefill_chunked(self, state, job: PrefillJob, slot_ids, *,
                               return_rows: bool = False):
        """Finalize a completed job and insert its rows into the live
        state (same donated masked insert as ``admit_slots``). ``slot_ids``
        addresses the real rows; dummy padding rows map to -1 (no-op).
        Returns (state', greedy first tokens [n_real]); with
        ``return_rows`` also the finalized rows (batch axis = group width,
        real rows first) so callers can snapshot them into the prefix
        store — the insert does not donate them."""
        assert job.finished
        logits, rows = self.model.prefill_finalize(
            self.params, job.carry, self.policy, s_total=job.s_total)
        ids = list(slot_ids) + [-1] * (logits.shape[0] - len(slot_ids))
        state = cache_lib.update_slots_donated(
            state, jnp.asarray(ids, jnp.int32), rows)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if return_rows:
            return state, first[:job.n_real], rows
        return state, first[:job.n_real]

    # ---- prefix-reuse resume (serving/prefix_cache.py) --------------------

    @_meshed
    def start_prefill_resumed(self, rows, batch: dict, *, s_prefix: int,
                              chunk_size: int) -> PrefillJob:
        """Open a chunked prefill that CONTINUES from restored prefix rows
        (a partial prefix-cache hit): ``batch["tokens"]`` holds ONLY the
        suffix, the plan covers suffix tokens, and the working buffer
        starts as the snapshot (K/V + scales + RASR scores + budget state)
        instead of empty. Compression engages when the *restored live
        occupancy* plus the suffix would overflow capacity — a policy that
        cannot evict raises the same typed ``ValueError`` as cold
        admission (callers fall back to a cold prefill)."""
        tokens = np.asarray(batch["tokens"])
        k, s_suffix = tokens.shape
        assert s_suffix > 0, "full hits insert rows directly, not resume"
        if not isinstance(rows, cache_lib.KVCache):
            raise ValueError(
                "prefix resume requires a bare slotted KV cache state")
        s_total = s_prefix + s_suffix
        plan = chunk_plan(s_suffix, chunk_size)
        C = self.policy.capacity
        live = int(np.asarray(rows.length).max()) if rows.length.size else 0
        compress = live + s_suffix > C
        if compress and not self.policy.prunes:
            raise ValueError(
                f"restored prefix ({live} live) + suffix ({s_suffix}) "
                f"exceeds capacity {C} and policy {self.policy.kind!r} "
                "cannot evict")
        carry = self.model.prefill_chunk_resume(
            self.params, rows, self.policy, chunk_max=max(plan),
            s_prefix=s_prefix, cache_dtype=self.cache_dtype)
        return PrefillJob(carry=carry,
                          batch={"tokens": jnp.asarray(tokens)},
                          plan=plan, s_total=s_total, compress=compress,
                          n_real=k, resumed=True)

    @_meshed
    def resume_prefill_rows(self, rows, batch: dict, *, s_prefix: int,
                            chunk_size: int = 32,
                            max_keep: int | None = None):
        """One-shot resumed prefill WITHOUT inserting (the front door's
        partial-hit admission primitive, mirroring ``prefill_rows``):
        returns (last-token logits [k, V], finalized rows). ``max_keep``
        applies the same degraded-admission compression round as a cold
        admission under pressure."""
        job = self.start_prefill_resumed(rows, batch, s_prefix=s_prefix,
                                         chunk_size=chunk_size)
        while not job.finished:
            job = self.prefill_chunk_step(job)
        logits, out = self.model.prefill_finalize(
            self.params, job.carry, self.policy, s_total=job.s_total)
        if max_keep is not None and max_keep < self.policy.capacity:
            out = self._degrade_rows(out, job.s_total - 1, max_keep)
        return logits, out

    @_meshed
    def admit_slots_chunked(self, state, slot_ids, batch: dict, *,
                            chunk_size: int, pad_rows_to: int | None = None):
        """One-shot chunked admission (start -> every chunk -> insert):
        differentially equal to ``admit_slots`` for prompts that fit
        capacity, and the only admission path for prompts that don't."""
        job = self.start_prefill_chunked(batch, chunk_size=chunk_size,
                                         pad_rows_to=pad_rows_to)
        while not job.finished:
            job = self.prefill_chunk_step(job)
        return self.finish_prefill_chunked(state, job, slot_ids)

    @_meshed
    def release_slots(self, state, slot_ids, *, pad_to: int | None = None):
        """Retire a group of slots back to empty (K/V zeroed, pos −1,
        occupancy 0, eviction threshold parked at capacity). ``pad_to``
        right-pads the id list with -1 (no-op) so every call shares one
        compiled program regardless of how many slots retire."""
        ids = list(slot_ids)
        if pad_to is not None:
            ids += [-1] * (pad_to - len(ids))
        return cache_lib.reset_slots_donated(state,
                                             jnp.asarray(ids, jnp.int32))

    def release_slot(self, state, slot: int):
        """Single-slot form of ``release_slots``."""
        return self.release_slots(state, [slot])

    @_meshed
    def decode_segment(self, state, tok, pos, done, n_steps: int, *,
                       eos_id: int | None = None):
        """Run ``n_steps`` greedy decode steps over the live batch with
        per-row positions — the inner loop of continuous batching, one
        ``lax.scan`` per segment.

        ``tok``/``pos``/``done``: [B] — each slot's last emitted token, its
        next position, and whether it is finished (finished/empty slots keep
        stepping but emit frozen ``eos_id`` tokens; their wasted work is
        bounded by the segment length, which is the scheduler's refill
        granularity). Returns (state', tokens [B, n_steps], pos', done').
        """
        key = (n_steps, eos_id)
        fn = self._segment_cache.get(key)
        if fn is None:
            model, params, policy = self.model, self.params, self.policy

            @functools.partial(jax.jit, donate_argnums=(0,))
            def fn(state, tok, pos, done):
                def step(carry, _):
                    state, tok, pos, done = carry
                    logits, state = model.module.decode_step(
                        params, state, tok, pos, model.cfg, policy)
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    if eos_id is not None:
                        nxt = jnp.where(done, eos_id, nxt)
                        done = done | (nxt == eos_id)
                    return (state, nxt, pos + 1, done), nxt

                (state, tok, pos, done), toks = jax.lax.scan(
                    step, (state, tok, pos, done), None, length=n_steps)
                return state, jnp.swapaxes(toks, 0, 1), pos, done

            self._segment_cache[key] = fn
        return fn(state, jnp.asarray(tok, jnp.int32),
                  jnp.asarray(pos, jnp.int32), jnp.asarray(done, bool))

    @_meshed
    def decode_segment_guarded(self, state, tok, pos, done, n_steps: int, *,
                               eos_id: int | None = None,
                               nan_pos=None, fault_pos=None):
        """Fault-isolated form of ``decode_segment``: same per-row greedy
        segment, plus per-row fault *detection* (a row whose logits go
        non-finite is flagged, not allowed to poison the harvest) and two
        chaos-injection hooks used by the robustness battery:

        * ``nan_pos`` [B] int32 — inject NaN into row i's logits at the
          step whose (absolute) position equals ``nan_pos[i]``; -1 = off.
        * ``fault_pos`` [B] int32 — flag row i as faulted at that position
          without touching its logits (a simulated per-row kernel fault).

        Both are *traced* arguments selected per-row, so the fault-free run
        and a chaos run execute the SAME compiled program — which is what
        makes "surviving rows are bit-identical to a fault-free run" a
        structural guarantee rather than a numerical accident.

        Returns (state', tokens [B, n_steps], pos', done', first_bad [B],
        bad_kind [B]) where ``first_bad[i]`` is the segment-step index of
        row i's first faulty token (``n_steps`` = row stayed healthy):
        tokens at steps ``< first_bad[i]`` are trustworthy, later ones are
        not. ``bad_kind[i]`` classifies the first fault — ``BAD_NAN`` for
        non-finite logits (real or injected), ``BAD_FAULT`` for a flagged
        row fault, 0 for a healthy row — so the front door's retry ladder
        and the ``failure_detail`` taxonomy report *cause*, not just
        position.
        """
        key = ("guarded", n_steps, eos_id)
        fn = self._segment_cache.get(key)
        if fn is None:
            model, params, policy = self.model, self.params, self.policy

            @functools.partial(jax.jit, donate_argnums=(0,))
            def fn(state, tok, pos, done, nan_pos, fault_pos):
                B = tok.shape[0]

                def step(carry, t):
                    state, tok, pos, done, first_bad, bad_kind = carry
                    logits, state = model.module.decode_step(
                        params, state, tok, pos, model.cfg, policy)
                    logits = jnp.where((pos == nan_pos)[:, None],
                                       jnp.float32(jnp.nan), logits)
                    is_nan = ~jnp.isfinite(logits).all(axis=-1)
                    bad_now = is_nan | (pos == fault_pos)
                    fresh = bad_now & (first_bad == n_steps)
                    bad_kind = jnp.where(
                        fresh,
                        jnp.where(is_nan, jnp.int32(BAD_NAN),
                                  jnp.int32(BAD_FAULT)),
                        bad_kind)
                    first_bad = jnp.where(fresh, t, first_bad)
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    if eos_id is not None:
                        nxt = jnp.where(done, eos_id, nxt)
                        done = done | (nxt == eos_id)
                    return (state, nxt, pos + 1, done, first_bad,
                            bad_kind), nxt

                first0 = jnp.full((B,), n_steps, jnp.int32)
                kind0 = jnp.zeros((B,), jnp.int32)
                (state, tok, pos, done, first_bad, bad_kind), toks = \
                    jax.lax.scan(
                        step, (state, tok, pos, done, first0, kind0),
                        jnp.arange(n_steps, dtype=jnp.int32))
                return (state, jnp.swapaxes(toks, 0, 1), pos, done,
                        first_bad, bad_kind)

            self._segment_cache[key] = fn
        B = len(tok)
        off = jnp.full((B,), -1, jnp.int32)
        return fn(state, jnp.asarray(tok, jnp.int32),
                  jnp.asarray(pos, jnp.int32), jnp.asarray(done, bool),
                  off if nan_pos is None else jnp.asarray(nan_pos,
                                                          jnp.int32),
                  off if fault_pos is None else jnp.asarray(fault_pos,
                                                            jnp.int32))

    @_meshed
    def prefill_rows(self, batch: dict, *, chunk_size: int = 32,
                     max_keep: int | None = None):
        """Prefill one admission group WITHOUT inserting it: returns
        (last-token logits [k, V], rows decode-state with batch axis k) so
        the caller can inspect/degrade the rows before committing them to
        live slots — the front door's admission primitive.

        Prompts that fit capacity run the whole-prompt prefill; longer ones
        stream through the chunked path's mid-prefill compression (and a
        policy that cannot evict raises ``ValueError`` there, which the
        front door maps to a typed ``rejected``). ``max_keep`` applies the
        degraded-admission compression round: the freshly prefilled rows
        are forced down to at most ``max_keep`` live tokens per layer
        before insertion (attention-family caches only).
        """
        s_total = self.model.total_prompt_len(batch)
        if s_total <= self.policy.capacity:
            logits, rows = self.prefill(batch)
        else:
            self.model.chunked_compress(self.policy, s_total)  # may raise
            logits, rows = self.model.prefill_chunked(
                self.params, batch, self.policy,
                chunk_plan=chunk_plan(s_total, chunk_size),
                cache_dtype=self.cache_dtype)
        if max_keep is not None and max_keep < self.policy.capacity:
            rows = self._degrade_rows(rows, s_total - 1, max_keep)
        return logits, rows

    @_meshed
    def _degrade_rows(self, rows, cur_pos: int, max_keep: int):
        """Tighten freshly prefilled rows to a ``max_keep`` occupancy
        ceiling (the compress rung of the degradation ladder). Only
        attention-family states whose decode state is the bare slotted
        cache participate; anything else passes through unchanged."""
        if not isinstance(rows, cache_lib.KVCache) or not self.policy.prunes:
            return rows
        key = ("degrade", max_keep)
        fn = self._segment_cache.get(key)
        if fn is None:
            from repro.core import pruning
            from repro.models.transformer import layer_windows
            policy, windows = self.policy, layer_windows(self.model.cfg)

            @jax.jit
            def fn(rows, cur):
                out = jax.vmap(
                    lambda lay, w: pruning.compress_prefill_layer(
                        lay, cur, policy=policy, max_keep=max_keep,
                        window=w))(rows, windows)
                # Pull the eviction threshold down too, so the degraded row
                # re-prunes at the tighter ceiling as it grows back (LETHE's
                # per-step allocator may later raise it again — the degrade
                # is an admission-pressure relief, not a permanent demotion).
                return replace(out, evict_at=jnp.minimum(
                    out.evict_at, jnp.int32(max_keep)))

            self._segment_cache[key] = fn
        return fn(rows, jnp.asarray(cur_pos, jnp.int32))

    def slot_lengths(self, state) -> np.ndarray:
        """Per-slot live-token occupancy, maxed over layers/caches ([B]).
        Telemetry for the capacity invariant: never exceeds ``capacity``."""
        caches = [x for x in jax.tree.leaves(
            state, is_leaf=lambda t: isinstance(t, cache_lib.KVCache))
            if isinstance(x, cache_lib.KVCache)]
        if not caches:
            return np.zeros((0,), np.int32)
        return np.max(np.stack([np.asarray(c.length).max(axis=0)
                                for c in caches]), axis=0)
