"""Serving-mesh plumbing: tensor-parallel continuous batching end-to-end.

``ServingMesh`` binds a 2-D ``jax.sharding.Mesh`` — batch/slot axis on
``data``, tensor parallel on ``model`` — to the live serving path:

* **Params** are placed once at ``Engine`` construction via the production
  sharding rules (``launch/shardings.param_specs``): Megatron-style head /
  d_ff column splits on ``model`` with divisibility-aware fallbacks.
* **Decode state** (KV payload, int8 scales, RASR scores, per-row budget /
  evict_at / sparsity) is placed by ``shardings.state_specs(serving=True)``:
  kv-heads on ``model``, slots on ``data``, and the capacity axis C always
  shard-local — pruning/compaction (``prune_layer``,
  ``compress_prefill_layer``) and the slot masked-selects
  (``tree_update_slots`` / ``reset_slot`` / ``append_token``) are
  elementwise over C, so they run per-shard with zero collectives.
* **Activation context** — every engine entry point runs under
  ``with mesh:``, which (a) lets ``models/shard_hints.hint`` constraints
  bind, and (b) lets ``kernels/ops.decode_attention_fused`` dispatch the
  shard_map-wrapped Pallas decode kernel with its partial-softmax
  all-reduce epilogue (the jit trace cache keys on the ambient mesh
  context, so mesh and no-mesh engines never share a traced program).

Host round trips stay mesh-safe for free: ``cache.extract_slots`` gathers
through ``np.asarray`` (an implicit device->host collect on an addressable
sharded array) and ``insert_slots`` scatters host rows back through the
donated masked select, so preemption-to-host and the prefix store work
unchanged — the prefix-store *fingerprint* additionally records the mesh
topology (``topology_token``) so snapshots captured under one sharding
never hit under another.

The no-mesh path is untouched: ``mesh=None`` engines run exactly the
pre-mesh code (a ``nullcontext`` around the same calls).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh

from repro.launch import shardings


def parse_mesh_arg(spec: str) -> tuple[int, int]:
    """``"dp,tp"`` -> (data, model) axis sizes. ``"2,4"`` = 2-way data
    parallel x 4-way tensor parallel over the first 8 devices."""
    parts = spec.split(",")
    if len(parts) != 2:
        raise ValueError(
            f"--mesh expects 'dp,tp' (two comma-separated ints), got "
            f"{spec!r}")
    try:
        dp, tp = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(
            f"--mesh expects 'dp,tp' (two comma-separated ints), got "
            f"{spec!r}") from None
    if dp < 1 or tp < 1:
        raise ValueError(f"--mesh axis sizes must be >= 1, got {spec!r}")
    return dp, tp


@dataclass
class ServingMesh:
    """A (data=dp, model=tp) mesh bound to the serving engine."""
    mesh: Mesh
    dp: int
    tp: int

    @classmethod
    def build(cls, spec: "str | tuple[int, int]",
              devices=None) -> "ServingMesh":
        """Build from ``"dp,tp"`` (or a (dp, tp) tuple) over the first
        dp*tp available devices. Raises with the fix (the
        ``xla_force_host_platform_device_count`` XLA flag) when the host
        does not expose enough devices."""
        dp, tp = (parse_mesh_arg(spec) if isinstance(spec, str) else
                  (int(spec[0]), int(spec[1])))
        devices = list(devices if devices is not None else jax.devices())
        need = dp * tp
        if len(devices) < need:
            raise ValueError(
                f"mesh {dp}x{tp} needs {need} devices but only "
                f"{len(devices)} are visible; on a CPU host set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
                "before the first jax import")
        mesh = Mesh(np.array(devices[:need]).reshape(dp, tp),
                    ("data", "model"))
        return cls(mesh=mesh, dp=dp, tp=tp)

    # ---- identity ---------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp

    def topology(self) -> dict:
        """Axis names/sizes + device identity — recorded in benchmark
        config blocks and serving run summaries."""
        return {
            "axes": {str(a): int(s) for a, s in
                     zip(self.mesh.axis_names,
                         self.mesh.devices.shape)},
            "n_devices": self.n_devices,
            "platform": self.mesh.devices.flat[0].platform,
        }

    def topology_token(self) -> str:
        """Canonical string form of the topology (prefix-store fingerprint
        component: snapshots captured under one sharding must never hit a
        lookup under another — the per-shard byte layout differs)."""
        axes = ",".join(f"{a}={s}" for a, s in
                        zip(self.mesh.axis_names, self.mesh.devices.shape))
        return f"mesh({axes})"

    # ---- placement --------------------------------------------------------

    def shard_params(self, params, cfg):
        """Place a param tree on the mesh per the production rules."""
        specs = shardings.param_specs(params, cfg, self.mesh)
        return jax.device_put(params, shardings.to_named(specs, self.mesh))

    def state_shardings(self, state, cfg, batch_slots: int):
        """NamedSharding tree for a live decode state (serving layout:
        C always shard-local)."""
        specs = shardings.state_specs(state, cfg, self.mesh, batch_slots,
                                      serving=True)
        return shardings.to_named(specs, self.mesh)

    def shard_state(self, state, cfg, batch_slots: int):
        """Place a freshly initialised decode state on the mesh."""
        return jax.device_put(
            state, self.state_shardings(state, cfg, batch_slots))


def mesh_context(mesh: "ServingMesh | None"):
    """``with mesh.mesh:`` when a mesh is bound, else a no-op — the single
    switch that keeps the no-mesh serving path byte-for-byte the pre-mesh
    program (the ambient-mesh trace-cache key separates the two)."""
    if mesh is None:
        return contextlib.nullcontext()
    return mesh.mesh
