"""SLO-aware serving front door: admission control, load shedding,
preemption-to-host, and fault-isolated decoding under live traffic.

The continuous-batching ``Scheduler`` replays a static request list in a
closed loop: no priorities, no deadlines, no overload behavior, and a
poisoned request is indistinguishable from a healthy batch. This module is
the production-shaped layer above the same ``Engine`` primitives:

* ``FrontDoorCore`` — a *deterministic, synchronous* state machine (every
  robustness guarantee is tested by stepping it directly):

  - **priorities / deadlines / decode timeouts** per request, with typed
    terminal reasons (``scheduler.FINISH_REASONS``);
  - **admission control + load shedding** driven by a memory-pressure
    signal derived from ``memory_breakdown`` (live cache bytes/tokens) plus
    queued demand. The degradation ladder, in order:
        compress  — admissions are force-compressed to a tighter
                    ``max_keep`` occupancy ceiling (less HBM per request);
        int8      — live migration of the whole decode state to the
                    block-scaled int8 layout (halved payload bytes; the
                    engine is swapped for an ``kv_format="int8"`` twin);
        shed      — lowest-priority queued work is dropped (``shed``);
        reject    — new arrivals are refused (``rejected``).
  - **preemption to host memory** — a low-priority resident's slot (KV
    payload + dequant scales + RASR scores + per-row budget state + the
    host-side decode cursor) is snapshotted to host RAM via
    ``cache.extract_slot``, the slot freed for a higher-priority arrival,
    and the request later re-admitted **bit-exactly** via
    ``cache.insert_slot`` — per-row state is the whole request state, so
    resumed tokens equal an uninterrupted run's.
  - **fault isolation** — non-finite logits (real or chaos-injected),
    inadmissible prompts, and injected mid-segment row faults terminate
    only the affected request (``failed``/``rejected``) while the rest of
    the batch keeps decoding; the guarded decode segment runs the SAME
    compiled program with and without chaos, so survivors are bit-identical
    to a fault-free run by construction.

* ``FrontDoor`` — the asyncio shell: open-loop arrivals (``submit`` /
  ``stream``), per-token streaming at segment granularity, device work off
  the event loop in an executor. ``benchmarks/slo_serving.py`` drives it
  with Poisson arrivals and reports goodput @ p99 TTFT/ITL SLOs.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.serving.durability import Durability, DurabilityConfig
from repro.serving.engine import BAD_FAULT, Engine, _cache_stats
from repro.serving.prefix_cache import PrefixCache, prefix_fingerprint
from repro.serving.scheduler import (DECODING, FAILURE_DETAILS, FINISHED,
                                     FINISH_REASONS, PREEMPTED, PREFILLING,
                                     QUEUED, Completion)


def _tree_row(tree, j: int):
    """Batch-axis-1 slice of one row out of an ``extract_slots`` host
    pytree (batch is always axis 1 in the slotted layout)."""
    return jax.tree.map(lambda x: np.asarray(x)[:, j:j + 1], tree)


@dataclass
class ServeRequest:
    """One front-door request: the scheduler's ``Request`` plus SLO state.

    ``priority``: higher = more urgent; outranking arrivals may preempt
    residents. ``deadline_s``: wall-clock budget from submission to
    completion (queued or decoding; exceeded -> ``timeout``).
    ``decode_timeout_s``: budget from first token to completion.
    """
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    priority: int = 0
    deadline_s: float | None = None
    decode_timeout_s: float | None = None


@dataclass
class AdmissionConfig:
    """The overload state machine's thresholds (DESIGN.md §Robustness).

    ``pressure`` = live-token occupancy of the cache pool + queued demand
    in units of the pool (1.0 = queued work alone would fill every slot to
    capacity). The ladder degrades cheapest-first: compress admissions,
    then migrate the pool to int8, then shed queued low-priority work,
    then reject arrivals.
    """
    max_queue: int | None = None       # hard queue cap; beyond -> rejected
    max_admit_factor: float = 2.0      # prompt > factor*capacity -> rejected
    prefill_chunk_size: int = 32       # long prompts stream through this
    compress_at: float = 1.25          # rung 1: tighter admission max_keep
    compress_keep_frac: float = 0.5    #   max_keep = frac * capacity
    int8_at: float | None = None       # rung 2: live int8 migration (None=off)
    int8_patience: int = 2             #   consecutive boundaries over int8_at
    shed_at: float = 3.0               # rung 3: shed low-priority queued
    reject_at: float = 6.0             # rung 4: reject new arrivals
    enable_shed: bool = True
    enable_preempt: bool = True


@dataclass
class ChaosConfig:
    """Fault-injection hooks (robustness battery). Keys are request uids;
    values are generated-token indices (>= 1 — token 0 comes from the
    prefill logits) at which the fault fires during decode.

    ``persistent``: by default an injection is *transient* — it fires at
    most once per (uid, kind), so a retry ladder can recover past it (the
    hardware-glitch model). ``persistent=True`` re-arms it every segment
    (the broken-row model), which is what drives a retry ladder to
    ``retry_exhausted`` + slot quarantine.
    """
    nan_logits_at: dict[int, int] = field(default_factory=dict)
    fault_at: dict[int, int] = field(default_factory=dict)
    persistent: bool = False


@dataclass
class RetryConfig:
    """Transient-fault retry ladder (DESIGN.md §Durability). A faulted row
    (non-finite logits / flagged row fault) rolls back to its last good
    pre-segment snapshot and re-queues with exponential backoff:
    ``min(backoff_base_s * 2**(attempt-1), backoff_cap_s)``. After
    ``max_retries`` failed attempts the slot is quarantined (never reused
    this process) and the request fails with ``retry_exhausted``."""
    max_retries: int = 3
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 1.0


@dataclass
class _Entry:
    req: ServeRequest
    submit_ts: float
    seq: int                          # FIFO tiebreak within a priority
    queue_depth: int
    tokens: list = field(default_factory=list)
    preemptions: int = 0
    admit_ts: float = 0.0
    first_token_ts: float | None = None
    ttft_steps: int = 0
    # preemption snapshot: (host rows pytree, last token, next position)
    snapshot: tuple | None = None
    prefix_hit: str = "miss"          # "full" | "partial" | "miss"
    # durability watermarks (token-list offsets): tokens below emit_from
    # were already streamed to the client in a previous incarnation of the
    # process (recomputed bit-exactly, never re-emitted); tokens below
    # journaled are already durable in the write-ahead journal.
    emit_from: int = 0
    journaled: int = 0
    # transient-fault retry ladder state
    retries: int = 0
    retry_after: float = 0.0          # backoff: not admissible before this
    good: tuple | None = None         # last clean pre-segment snapshot
    failure_detail: str | None = None


class FrontDoorCore:
    """Deterministic synchronous core of the serving front door.

    Drives the live batch one boundary at a time: ``step()`` = ingest
    staged arrivals -> expire deadlines -> degradation ladder -> preempt /
    admit -> one guarded decode segment -> harvest. Tests step it directly
    (with an injectable ``clock``) so every overload path is reproducible;
    the asyncio ``FrontDoor`` is a thin shell around it.
    """

    def __init__(self, engine: Engine, batch_slots: int, *,
                 segment_len: int = 8, eos_id: int | None = None,
                 admission: AdmissionConfig | None = None,
                 chaos: ChaosConfig | None = None,
                 retry: RetryConfig | None = None,
                 durability: "Durability | DurabilityConfig | str | None"
                 = None,
                 prefix_cache: PrefixCache | None = None,
                 clock: Callable[[], float] = time.perf_counter,
                 mesh=None):
        self.eng = engine
        # Mesh-sharded serving: the engine owns the mesh; the front door
        # adopts it (fingerprint + telemetry) and re-binds it across the
        # int8 migration rung. Explicit ``mesh`` must agree.
        if mesh is not None and mesh is not engine.mesh:
            raise ValueError(
                "FrontDoorCore(mesh=...) must be the engine's own "
                "ServingMesh (pass mesh= to Engine; the core adopts it)")
        self.batch_slots = batch_slots
        self.segment_len = segment_len
        self.eos_id = eos_id
        self.adm = admission or AdmissionConfig()
        self.chaos = chaos or ChaosConfig()
        self.prefix_cache = prefix_cache
        self._fp = self._fingerprint()
        self.clock = clock

        B = batch_slots
        self.state = engine.new_decode_state(B)
        stats = _cache_stats(self.state)
        self._cache_bytes = stats["cache_bytes"]
        self._kv_format = stats["kv_format"]
        self._cap_tokens = max(stats["capacity_tokens"], 1)

        self.slots: list[_Entry | None] = [None] * B
        self.tok = np.zeros((B,), np.int32)
        self.pos = np.zeros((B,), np.int32)
        self.done = np.ones((B,), bool)
        self.queue: list[_Entry] = []       # kept priority-sorted at use
        self.completed: list[Completion] = []
        self.lifecycle: dict[int, list[str]] = {}
        self._staged: list[ServeRequest] = []
        self._events_tok: list = []
        self._events_done: list = []
        self._seq = 0
        self._decode_steps = 0
        self.max_queue_depth = 0
        self.n_preemptions = 0
        self.pressure_trace: list[float] = []
        self._int8_strikes = 0
        self._migrated = False
        self._int8_disabled = False

        # transient-fault retry ladder (None = the pre-durability behavior:
        # a faulted row terminates as ``failed`` immediately)
        self.retry = retry
        self.quarantined: set[int] = set()
        self._chaos_fired: set[tuple[int, str]] = set()
        self.n_retries = 0

        # write-ahead journal + pool checkpoints (serving/durability.py);
        # accepts a Durability, a DurabilityConfig, or a bare root path
        if durability is not None and not isinstance(durability,
                                                     Durability):
            durability = Durability(durability)
        self.dur = durability
        if self.dur is not None:
            self.dur.log_open(self._fp)

    # ---- submission -------------------------------------------------------

    def submit(self, reqs: Iterable[ServeRequest]) -> None:
        """Stage arrivals; admission-control verdicts (reject vs queue)
        land at the next ``step()`` so the core stays single-threaded even
        under the asyncio shell."""
        self._staged.extend(reqs)

    @property
    def idle(self) -> bool:
        return (not self._staged and not self.queue
                and all(s is None for s in self.slots))

    # ---- pressure + ladder ------------------------------------------------

    def _queued_demand(self) -> float:
        """Queued work in units of the slot pool (1.0 = would fill every
        slot to capacity)."""
        C = self.eng.policy.capacity
        need = sum(min(len(e.req.prompt) + e.req.max_new_tokens, C)
                   for e in self.queue)
        return need / (self.batch_slots * C)

    def _occupancy(self) -> float:
        """Live-token occupancy of the cache pool (the device-sync half of
        the pressure signal — compute once per boundary, not per arrival)."""
        stats = _cache_stats(self.state)
        return stats["live_tokens"] / max(stats["capacity_tokens"], 1)

    def pressure(self) -> float:
        return self._occupancy() + self._queued_demand()

    def _fingerprint(self) -> bytes:
        """Prefix-store compatibility key for the CURRENT engine: policy
        config (capacity, kind, kv_format, every score/budget knob), cache
        dtype, arch identity and mesh topology. Recomputed after the int8
        migration rung — bf16-era entries then stop hitting instead of
        inserting the wrong payload layout."""
        return prefix_fingerprint(
            self.eng.policy, self.eng.cache_dtype,
            arch=self.eng.model.cfg.name,
            mesh=(self.eng.mesh.topology_token()
                  if self.eng.mesh is not None else ""))

    def _admission_max_keep(self, p: float) -> int | None:
        if p < self.adm.compress_at:
            return None
        return max(1, int(self.adm.compress_keep_frac
                          * self.eng.policy.capacity))

    def _migrate_int8(self) -> None:
        """Rung 2: migrate the live pool (and engine) to the int8 layout.
        Disabled permanently on the first failure (recurrent family, or a
        state that is not a slotted cache)."""
        try:
            pol8 = dataclasses.replace(self.eng.policy, kv_format="int8")
            eng8 = Engine(self.eng.model, self.eng.params, pol8,
                          cache_dtype=self.eng.cache_dtype,
                          mesh=self.eng.mesh)
        except ValueError:
            self._int8_disabled = True
            return
        self.state = cache_lib.quantize_tree_jit(self.state)
        self.eng = eng8
        self._migrated = True
        self._fp = self._fingerprint()
        stats = _cache_stats(self.state)
        self._cache_bytes = stats["cache_bytes"]
        self._kv_format = stats["kv_format"]

    def _ladder(self) -> float:
        p = self.pressure()
        self.pressure_trace.append(p)
        a = self.adm
        if (a.int8_at is not None and not self._migrated
                and not self._int8_disabled):
            self._int8_strikes = (self._int8_strikes + 1
                                  if p >= a.int8_at else 0)
            if self._int8_strikes >= a.int8_patience:
                self._migrate_int8()
        if a.enable_shed and p >= a.shed_at and self.queue:
            # shed lowest-priority queued work, youngest first, until the
            # backlog's demand share brings pressure back under the rung.
            # Entries with a slot path this boundary are exempt: the
            # top-priority entries that fit the free slots, and (when
            # preemption is on) anything that outranks a live resident —
            # shedding those would starve exactly the work the ladder is
            # trying to protect.
            free = len(self._free_ids())
            order = sorted(self.queue,
                           key=lambda e: (-e.req.priority, e.seq))
            protected = {id(e) for e in order[:free]}
            if a.enable_preempt:
                live = [s.req.priority for s in self.slots if s is not None]
                if live:
                    floor = min(live)
                    protected |= {id(e) for e in self.queue
                                  if e.req.priority > floor}
            cands = sorted((e for e in self.queue
                            if id(e) not in protected),
                           key=lambda e: (e.req.priority, -e.seq))
            occ = p - self._queued_demand()
            for e in cands:
                if occ + self._queued_demand() < a.shed_at:
                    break
                self.queue.remove(e)
                self._finish(e, "shed")
        return p

    # ---- terminal bookkeeping --------------------------------------------

    def _finish(self, e: _Entry, reason: str,
                detail: str | None = None) -> None:
        assert reason in FINISH_REASONS, reason
        if detail is None and reason == "failed":
            detail = e.failure_detail
        assert detail is None or detail in FAILURE_DETAILS, detail
        if self.dur is not None:          # write-ahead: exactly-once
            self.dur.log_terminal(e.req.uid, reason, detail)
        now = self.clock()
        toks = np.asarray(e.tokens, np.int32)
        resid = max(now - (e.admit_ts or now), 1e-9)
        self.lifecycle[e.req.uid].append(FINISHED)
        ttft = ((e.first_token_ts - e.submit_ts)
                if e.first_token_ts is not None else now - e.submit_ts)
        self.completed.append(Completion(
            uid=e.req.uid, tokens=toks, latency_steps=len(toks),
            finish_reason=reason,
            queue_wait_s=max((e.admit_ts or now) - e.submit_ts, 0.0),
            ttft_s=max(ttft, 0.0),
            decode_steps=max(len(toks) - 1, 0),
            tokens_per_second=len(toks) / resid,
            ttft_steps=e.ttft_steps,
            kv_format=self._kv_format, cache_bytes=self._cache_bytes,
            priority=e.req.priority, preemptions=e.preemptions,
            queue_depth=e.queue_depth, prefix_hit=e.prefix_hit,
            failure_detail=detail if reason == "failed" else None,
            retries=e.retries))
        self._events_done.append(self.completed[-1])

    def _release(self, i: int) -> None:
        self.state = self.eng.release_slots(self.state, [i],
                                            pad_to=self.batch_slots)
        self.slots[i] = None
        self.done[i] = True

    # ---- ingest + expiry --------------------------------------------------

    def _ingest(self) -> None:
        staged, self._staged = self._staged, []
        if not staged:
            return
        # One occupancy read (= one _cache_stats device sync) per ingest:
        # the live state cannot change between staged arrivals, only the
        # queued-demand half of the pressure signal does — recomputing the
        # full pressure per arrival was O(arrivals) syncs per boundary
        # under admission waves.
        occ = self._occupancy()
        for r in staged:
            if self.dur is not None:      # write-ahead: durable before any
                self.dur.log_submit(r)    # admission verdict is visible
            self._seq += 1
            e = _Entry(req=r, submit_ts=self.clock(), seq=self._seq,
                       queue_depth=len(self.queue))
            self.lifecycle[r.uid] = [QUEUED]
            self.max_queue_depth = max(self.max_queue_depth,
                                       len(self.queue) + 1)
            a = self.adm
            C = self.eng.policy.capacity
            if len(r.prompt) > a.max_admit_factor * C:
                self._finish(e, "rejected")
                continue
            if a.max_queue is not None and len(self.queue) >= a.max_queue:
                self._finish(e, "rejected")
                continue
            if (occ + self._queued_demand() >= a.reject_at
                    and not self._free_ids()):
                self._finish(e, "rejected")
                continue
            self.queue.append(e)

    def _slot_of(self, entry) -> int | None:
        for i, s in enumerate(self.slots):
            if s is entry:
                return i
        return None

    def _free_ids(self) -> list[int]:
        """Admissible free slots — quarantined slots (retry-exhausted
        faults) are never handed out again."""
        return [i for i in range(self.batch_slots)
                if self.slots[i] is None and i not in self.quarantined]

    def _expired(self, e: _Entry, now: float) -> bool:
        d = e.req.deadline_s
        if d is not None and now - e.submit_ts > d:
            return True
        t = e.req.decode_timeout_s
        return (t is not None and e.first_token_ts is not None
                and now - e.first_token_ts > t)

    def _expire(self) -> None:
        now = self.clock()
        for e in [q for q in self.queue if self._expired(q, now)]:
            self.queue.remove(e)
            self._finish(e, "timeout")
        for i, e in enumerate(self.slots):
            if e is not None and self._expired(e, now):
                self._finish(e, "timeout")
                self._release(i)

    # ---- preemption -------------------------------------------------------

    def preempt_slot(self, i: int) -> None:
        """Snapshot resident ``i`` to host RAM and free its slot. The
        snapshot is the complete per-request state (KV payload + scales +
        RASR scores + budget state + decode cursor); re-admission resumes
        bit-exactly. Public so tests can force preemption points."""
        e = self.slots[i]
        assert e is not None, i
        rows = cache_lib.extract_slots(self.state, [i])
        e.snapshot = (rows, int(self.tok[i]), int(self.pos[i]))
        e.preemptions += 1
        self.n_preemptions += 1
        self.lifecycle[e.req.uid].append(PREEMPTED)
        self.queue.append(e)
        self._release(i)

    def _resume(self, e: _Entry, i: int) -> None:
        rows, tok, pos = e.snapshot
        if self._migrated:
            # bf16 snapshot taken before the int8 rung fired: requantize on
            # the way in (int8 snapshots round-trip bit-exactly unchanged)
            rows = cache_lib.tree_quantize(rows)
        self.state = cache_lib.insert_slots(self.state, [i], rows)
        e.snapshot = None
        self.slots[i] = e
        self.tok[i], self.pos[i], self.done[i] = tok, pos, False
        self.lifecycle[e.req.uid].append(DECODING)

    # ---- admission --------------------------------------------------------

    def _admit(self, pressure: float) -> None:
        B = self.batch_slots
        # entries inside their retry backoff window are invisible to this
        # boundary's admission (and cannot trigger preemption)
        now = self.clock()
        waiting = [e for e in self.queue if e.retry_after > now]
        if waiting:
            self.queue = [e for e in self.queue if e.retry_after <= now]
        self.queue.sort(key=lambda e: (-e.req.priority, e.seq))
        free = self._free_ids()

        # preempt: queue head strictly outranks the lowest-priority
        # resident and no slot is free
        while (self.adm.enable_preempt and self.queue and not free):
            head = self.queue[0]
            live = [(self.slots[i].req.priority, -self.slots[i].seq, i)
                    for i in range(B) if self.slots[i] is not None]
            if not live:
                break
            vprio, _, victim = min(live)
            if head.req.priority <= vprio:
                break
            self.preempt_slot(victim)
            self.queue.sort(key=lambda e: (-e.req.priority, e.seq))
            free = self._free_ids()

        # resume preempted entries individually; group fresh admissions by
        # prompt length so a refill wave shares prefill programs
        while self.queue and free:
            fresh: dict[int, list] = {}
            n_take = len(free)
            taken, rest = self.queue[:n_take], self.queue[n_take:]
            self.queue = rest
            for e in taken:
                if e.snapshot is not None:
                    self._resume(e, free.pop(0))
                else:
                    fresh.setdefault(len(e.req.prompt), []).append(e)
            for _, group in sorted(fresh.items()):
                ids = [free.pop(0) for _ in group]
                self._admit_group(ids, group, pressure)
            # instant completions (EOS-at-first-token, rejected groups) may
            # have freed slots again — loop and refill them
            free = self._free_ids()
        self.queue.extend(waiting)

    def _journal_tokens(self, e: _Entry, off: int, toks: list[int]) -> None:
        """Append the suffix of ``toks`` (absolute offsets ``off..``) not
        yet covered by the entry's journal watermark. Recovered entries
        regenerate their pre-crash tokens bit-exactly — those fall below
        the watermark and are NOT re-journaled (the journal stays
        append-only with contiguous offsets across process incarnations)."""
        if self.dur is None or not toks:
            return
        end = off + len(toks)
        if end <= e.journaled:
            return
        start = max(e.journaled - off, 0)
        self.dur.log_tokens(e.req.uid, off + start, toks[start:])
        e.journaled = end

    def _go_live(self, e: _Entry, i: int, first: int) -> None:
        """Post-prefill bookkeeping shared by cold, full-hit and partial-hit
        admission: record the first token, then either finish immediately
        (EOS-at-first-token / 1-token budget) or bring the slot live."""
        off = len(e.tokens)
        e.tokens.append(int(first))
        e.first_token_ts = self.clock()
        e.ttft_steps = self._decode_steps
        self._journal_tokens(e, off, [int(first)])
        if off >= e.emit_from:        # at-most-once emission across crashes
            self._events_tok.append((e.req.uid, [int(first)]))
        if self.eos_id is not None and int(first) == self.eos_id:
            self._finish(e, "eos")
            self._release(i)
        elif e.req.max_new_tokens <= 1:
            self._finish(e, "length")
            self._release(i)
        else:
            self.lifecycle[e.req.uid].append(DECODING)
            self.slots[i] = e
            self.tok[i] = int(first)
            self.pos[i] = len(e.req.prompt)
            self.done[i] = False

    def _capture_prefix(self, e: _Entry, rows, j: int, first: int,
                        degraded: bool) -> None:
        """Snapshot row ``j`` of freshly finalized ``rows`` into the prefix
        store (the PR 5 extract path: a bit-exact host copy). Degraded
        admissions (the compress rung's ``max_keep``) are not captured —
        their rows embed pressure-relief state the fingerprint doesn't
        encode, and a later unpressured hit must not inherit it."""
        if self.prefix_cache is None or degraded:
            return
        self.prefix_cache.insert(
            self._fp, e.req.prompt,
            cache_lib.extract_slots(rows, [j]), int(first))

    def _admit_full_hit(self, e: _Entry, i: int, hit) -> None:
        """Full-prefix hit: the stored snapshot IS the finalize output, so
        insert it instead of running prefill — bit-identical to
        recomputation (the differential battery's claim)."""
        self.state = cache_lib.insert_slots(self.state, [i], hit.entry.rows)
        e.prefix_hit = "full"
        self._go_live(e, i, hit.entry.first_token)

    def _admit_partial_hit(self, e: _Entry, i: int, hit,
                           pressure: float) -> bool:
        """Partial hit: resume chunked prefill from the restored rows for
        the suffix only; capture the full-prompt entry so the store learns
        the longer prefix. Returns False when resume is inadmissible (the
        caller falls back to a cold prefill)."""
        suffix = np.asarray(e.req.prompt[hit.prefix_len:],
                            np.int32)[None, :]
        max_keep = self._admission_max_keep(pressure)
        try:
            logits, rows = self.eng.resume_prefill_rows(
                hit.entry.rows, {"tokens": suffix},
                s_prefix=hit.prefix_len,
                chunk_size=self.adm.prefill_chunk_size, max_keep=max_keep)
        except ValueError:
            return False
        e.prefix_hit = "partial"
        lg = np.asarray(logits[0])
        if not np.isfinite(lg).all():
            self._finish(e, "failed", detail="prefill_nonfinite")
            return True
        first = int(lg.argmax())
        self.state = cache_lib.insert_slots(self.state, [i], rows)
        self._capture_prefix(e, rows, 0, first,
                             degraded=max_keep is not None)
        self._go_live(e, i, first)
        return True

    def _admit_group(self, ids: list[int], group: list[_Entry],
                     pressure: float) -> None:
        admit_ts = self.clock()
        for e in group:
            self.lifecycle[e.req.uid].append(PREFILLING)
            e.admit_ts = admit_ts
            if self.dur is not None:
                self.dur.log_admit(e.req.uid)

        # -- prefix-store probe: full hits insert stored rows, partial hits
        # resume suffix prefill; only the misses pay a cold prefill --------
        if self.prefix_cache is not None:
            cold_ids, cold = [], []
            for i, e in zip(ids, group):
                hit = self.prefix_cache.lookup(self._fp, e.req.prompt)
                if hit is not None and hit.full:
                    self._admit_full_hit(e, i, hit)
                elif hit is not None and self._admit_partial_hit(
                        e, i, hit, pressure):
                    pass
                else:
                    cold_ids.append(i)
                    cold.append(e)
            ids, group = cold_ids, cold
            if not group:
                return

        prompts = np.stack([e.req.prompt for e in group]).astype(np.int32)
        max_keep = self._admission_max_keep(pressure)
        try:
            logits, rows = self.eng.prefill_rows(
                {"tokens": jnp.asarray(prompts)},
                chunk_size=self.adm.prefill_chunk_size,
                max_keep=max_keep)
        except ValueError:
            # inadmissible under this policy (e.g. FullKV + over-capacity):
            # reject the group, everyone else keeps decoding
            for e in group:
                self._finish(e, "rejected")
            return
        lg = np.asarray(logits)
        finite = np.isfinite(lg).all(axis=-1)
        first = lg.argmax(axis=-1).astype(np.int32)
        ins = [i if ok else -1 for i, ok in zip(ids, finite)]
        self.state = cache_lib.insert_slots(self.state, ins, rows)
        for j, (e, i, ok, f) in enumerate(zip(group, ids, finite, first)):
            if not ok:         # poisoned prompt: row never went live
                self._finish(e, "failed", detail="prefill_nonfinite")
                continue
            self._capture_prefix(e, rows, j, int(f),
                                 degraded=max_keep is not None)
            self._go_live(e, i, int(f))

    # ---- the boundary + segment ------------------------------------------

    def _chaos_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        B = self.batch_slots
        nan_pos = np.full((B,), -1, np.int32)
        fault_pos = np.full((B,), -1, np.int32)
        for i, e in enumerate(self.slots):
            if e is None:
                continue
            for table, out, kind in (
                    (self.chaos.nan_logits_at, nan_pos, "nan"),
                    (self.chaos.fault_at, fault_pos, "fault")):
                k = table.get(e.req.uid)
                if k is None or k < len(e.tokens):
                    continue
                if (not self.chaos.persistent
                        and (e.req.uid, kind) in self._chaos_fired):
                    continue      # transient fault already fired once
                # generated-token index k is produced by the decode
                # step consuming token k-1, i.e. at absolute position
                # prompt_len + k - 1
                out[i] = len(e.req.prompt) + k - 1
        return nan_pos, fault_pos

    def step(self) -> tuple[list, list]:
        """One scheduler boundary + one decode segment. Returns
        (token_events, completions) produced this step, where
        ``token_events`` is a list of (uid, [new tokens]) for streaming.

        With a durability layer bound, the boundary is ordered so every
        client-visible event is write-ahead journaled before it is exposed:
        submits/admits/first tokens during admission, harvested tokens
        after the segment, terminals last — and the four kill points
        (``after_admit``, ``mid_segment``, ``after_harvest``,
        ``mid_checkpoint``) sit exactly at the boundaries the recovery
        guarantees are proven over.
        """
        self._events_tok: list = []
        self._events_done: list = []
        self._ingest()
        self._expire()
        p = self._ladder()
        self._admit(p)
        if self.dur is not None:
            self.dur.crash("after_admit")

        to_reset = [i for i in range(self.batch_slots)
                    if self.slots[i] is None]
        if to_reset:
            self.state = self.eng.release_slots(self.state, to_reset,
                                                pad_to=self.batch_slots)
        active = [i for i in range(self.batch_slots)
                  if self.slots[i] is not None]
        if not active:
            self._maybe_checkpoint()
            return self._events_tok, self._events_done

        if self.retry is not None:
            # last-good capture: one host extract of the live rows at the
            # clean pre-segment boundary — the state a transient fault in
            # the coming segment rolls back to
            rows = cache_lib.extract_slots(self.state, active)
            for j, i in enumerate(active):
                e = self.slots[i]
                e.good = (_tree_row(rows, j), int(self.tok[i]),
                          int(self.pos[i]))

        nan_pos, fault_pos = self._chaos_arrays()
        self.state, seg, pos_j, done_j, first_bad, bad_kind = \
            self.eng.decode_segment_guarded(
                self.state, self.tok, self.pos, self.done,
                self.segment_len, eos_id=self.eos_id,
                nan_pos=nan_pos, fault_pos=fault_pos)
        seg = np.asarray(seg)
        first_bad = np.asarray(first_bad)
        bad_kind = np.asarray(bad_kind)
        self.pos, self.done = np.array(pos_j), np.array(done_j)
        self.tok = seg[:, -1].astype(np.int32)
        self._decode_steps += self.segment_len
        if self.dur is not None:
            self.dur.crash("mid_segment")

        now = self.clock()
        emits: list[tuple[_Entry, int, list[int]]] = []
        finals: list[tuple[int, _Entry, str]] = []
        rollbacks: list[tuple[int, _Entry, str]] = []
        for i in active:
            e = self.slots[i]
            want = e.req.max_new_tokens
            reason = None
            bad = int(first_bad[i])
            if bad < self.segment_len:
                detail = ("row_fault" if int(bad_kind[i]) == BAD_FAULT
                          else "nan_logits")
                self._chaos_fired.add(
                    (e.req.uid,
                     "fault" if int(bad_kind[i]) == BAD_FAULT else "nan"))
                if self.retry is not None and e.good is not None:
                    # discard the whole segment for this row (the clean
                    # prefix regenerates bit-exactly from the snapshot —
                    # nothing emitted, so nothing can double-emit)
                    rollbacks.append((i, e, detail))
                    continue
                e.failure_detail = detail
            off0 = len(e.tokens)
            fresh: list[int] = []
            for s, t in enumerate(seg[i]):
                if s >= bad:
                    reason = "failed"
                    break
                e.tokens.append(int(t))
                fresh.append(int(t))
                if self.eos_id is not None and t == self.eos_id:
                    reason = "eos"
                    break
                if len(e.tokens) >= want:
                    reason = "length"
                    break
            if fresh:
                emits.append((e, off0, fresh))
            if reason is None and self._expired(e, now):
                reason = "timeout"
            if reason is not None:
                finals.append((i, e, reason))

        # entries are harvested but nothing is journaled or client-visible
        # yet — the kill point the write-ahead ordering is proven at
        if self.dur is not None:
            self.dur.crash("after_harvest")
        for e, off0, fresh in emits:
            self._journal_tokens(e, off0, fresh)
            vis = [t for k, t in enumerate(fresh) if off0 + k >= e.emit_from]
            if vis:
                self._events_tok.append((e.req.uid, vis))
        for i, e, reason in finals:
            self._finish(e, reason)
            self._release(i)
        for i, e, detail in rollbacks:
            self._rollback(i, e, detail)
        self._maybe_checkpoint()
        return self._events_tok, self._events_done

    # ---- transient-fault retry / durability hooks ------------------------

    def _rollback(self, i: int, e: _Entry, detail: str) -> None:
        """Roll a faulted row back to its last good pre-segment snapshot
        and re-queue it under exponential backoff — or, past the retry
        cap, quarantine the slot and fail with ``retry_exhausted``."""
        e.failure_detail = detail
        if e.retries >= self.retry.max_retries:   # budget already spent:
            self.quarantined.add(i)               # this fault is terminal,
            self._finish(e, "failed", detail="retry_exhausted")  # not a
            self._release(i)                      # retry
            return
        e.retries += 1
        self.n_retries += 1
        rows, tok, pos = e.good
        e.snapshot = (rows, tok, pos)
        back = min(self.retry.backoff_base_s * (2 ** (e.retries - 1)),
                   self.retry.backoff_cap_s)
        e.retry_after = self.clock() + back
        self.lifecycle[e.req.uid].append(PREEMPTED)
        self.queue.append(e)
        self._release(i)

    def _checkpoint_entries(self) -> list[tuple]:
        """Everything with KV state worth persisting: live rows (one host
        extract) plus queued preemption/retry snapshots. Each entry is
        (uid, rows[batch=1], last token, next pos, tokens generated)."""
        entries: list[tuple] = []
        live = [i for i in range(self.batch_slots)
                if self.slots[i] is not None]
        if live:
            rows = cache_lib.extract_slots(self.state, live)
            for j, i in enumerate(live):
                e = self.slots[i]
                entries.append((e.req.uid, _tree_row(rows, j),
                                int(self.tok[i]), int(self.pos[i]),
                                len(e.tokens)))
        for e in self.queue:
            if e.snapshot is not None:
                rows, tok, pos = e.snapshot
                if self._migrated:
                    # keep the checkpoint layout-uniform with the live pool
                    # (mirrors _resume's requantize-on-the-way-in)
                    rows = cache_lib.tree_quantize(rows)
                entries.append((e.req.uid, rows, tok, pos, len(e.tokens)))
        return entries

    def _checkpoint_now(self) -> int | None:
        if self.dur is None:
            return None
        return self.dur.write_pool_checkpoint(self._fp,
                                              self._checkpoint_entries())

    def _maybe_checkpoint(self) -> None:
        if self.dur is not None and self.dur.checkpoint_due():
            self._checkpoint_now()

    def shutdown(self, *, checkpoint: bool = True) -> dict:
        """Graceful drain (the SIGTERM path): journal anything staged but
        not yet ingested (so a restart replays it), checkpoint every row
        holding KV state, seal the journal. The core must not be stepped
        afterwards; ``durability.recover`` rebuilds the outstanding work
        in a fresh process."""
        info = {
            "live": sum(s is not None for s in self.slots),
            "queued": len(self.queue),
            "staged": len(self._staged),
            "checkpoint_seq": None,
        }
        if self.dur is not None:
            for r in self._staged:
                self.dur.log_submit(r)
            if checkpoint:
                info["checkpoint_seq"] = self._checkpoint_now()
            self.dur.seal()
        return info

    def run(self) -> list[Completion]:
        """Drain synchronously (closed-loop form, mirrors
        ``Scheduler.run``): step until idle; completions uid-ordered."""
        while not self.idle:
            self.step()
        self.completed.sort(key=lambda c: c.uid)
        return self.completed

    def run_summary(self) -> dict:
        by_reason = {r: 0 for r in FINISH_REASONS}
        details: dict[str, int] = {}
        for c in self.completed:
            by_reason[c.finish_reason] += 1
            if c.failure_detail is not None:
                details[c.failure_detail] = details.get(c.failure_detail,
                                                        0) + 1
        return {
            "completed": len(self.completed),
            "finish_reasons": by_reason,
            "shed": by_reason["shed"],
            "preempted": self.n_preemptions,
            "timeout": by_reason["timeout"],
            "failed": by_reason["failed"],
            "failure_details": details,
            "retries": self.n_retries,
            "quarantined_slots": sorted(self.quarantined),
            "rejected": by_reason["rejected"],
            "max_queue_depth": self.max_queue_depth,
            "decode_steps": self._decode_steps,
            "kv_format": self._kv_format,
            "mesh": (self.eng.mesh.topology() if self.eng.mesh is not None
                     else None),
            "peak_pressure": max(self.pressure_trace, default=0.0),
            "prefix_full_hits": sum(c.prefix_hit == "full"
                                    for c in self.completed),
            "prefix_partial_hits": sum(c.prefix_hit == "partial"
                                       for c in self.completed),
            "prefix_cache": (self.prefix_cache.stats()
                             if self.prefix_cache is not None else None),
            "durability": (self.dur.stats() if self.dur is not None
                           else None),
        }


class FrontDoor:
    """Asyncio shell over ``FrontDoorCore``: open-loop submission with
    per-token streaming. Device work runs in an executor so the event loop
    keeps accepting arrivals mid-segment.

    Usage::

        async with FrontDoor(engine, batch_slots=8, eos_id=2) as fd:
            comp = await fd.submit(req)            # or:
            async for tok in fd.stream(req): ...
    """

    _DONE = object()

    def __init__(self, engine: Engine, batch_slots: int, *,
                 completions_keep: int = 1024,
                 core: FrontDoorCore | None = None, **core_kw):
        # ``core=`` accepts a prebuilt FrontDoorCore — the restart path
        # (``durability.recover``) returns one with the journal's
        # outstanding requests already queued/resumable.
        if core is not None and core_kw:
            raise ValueError("pass either core= or core kwargs, not both")
        self.core = core or FrontDoorCore(engine, batch_slots, **core_kw)
        # All three maps are bounded for a long-lived server: futures and
        # stream queues are dropped as their request completes, finished
        # Completions are kept in a FIFO ring of ``completions_keep`` (the
        # full uid-ordered history stays on ``core.completed``).
        self.completions_keep = completions_keep
        self._futures: dict[int, asyncio.Future] = {}
        self._streams: dict[int, asyncio.Queue] = {}
        self._completions: "collections.OrderedDict[int, Completion]" = \
            collections.OrderedDict()
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        self._stopping = False
        self._halt = False
        self._parked = False

    async def __aenter__(self) -> "FrontDoor":
        self._wake = asyncio.Event()
        self._task = asyncio.get_running_loop().create_task(self._loop())
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def _enqueue(self, req: ServeRequest) -> asyncio.Future:
        fut = asyncio.get_running_loop().create_future()
        self._futures[req.uid] = fut
        self.core.submit([req])
        self._wake.set()
        return fut

    async def submit(self, req: ServeRequest) -> Completion:
        """Submit one request; resolves to its (typed) Completion."""
        return await self._enqueue(req)

    async def stream(self, req: ServeRequest):
        """Submit one request and yield its tokens as they decode
        (segment-granularity). The final Completion is available from
        ``completion(uid)`` afterwards."""
        q: asyncio.Queue = asyncio.Queue()
        self._streams[req.uid] = q
        fut = self._enqueue(req)
        while True:
            item = await q.get()
            if item is self._DONE:
                break
            yield item
        self._remember(req.uid, await fut)

    def completion(self, uid: int) -> Completion | None:
        return self._completions.get(uid)

    @property
    def quiesced(self) -> bool:
        """True when the pump is parked on an EMPTY core. ``core.idle``
        alone is not enough for an outside observer: mid-``step()`` the
        admit path holds entries in neither queue nor slot for seconds
        (prefill), so the core looks idle while work is in flight.
        ``_parked`` is only set while the pump coroutine is suspended
        between steps, when ``core.idle`` is stable."""
        return self._parked and self.core.idle

    def _remember(self, uid: int, comp: Completion) -> None:
        """Record a completion in the bounded FIFO ring."""
        self._completions[uid] = comp
        self._completions.move_to_end(uid)
        while len(self._completions) > self.completions_keep:
            self._completions.popitem(last=False)

    async def drain(self) -> None:
        """Wait until every submitted request has completed — including
        requests submitted *after* the drain started (the gather re-snaps
        until no pending future remains)."""
        while True:
            futs = [f for f in self._futures.values() if not f.done()]
            if not futs:
                return
            await asyncio.gather(*futs, return_exceptions=True)

    async def stop(self) -> None:
        """Stop the pump. Safe before ``__aenter__`` (nothing started:
        no-op) and re-entrant (a second call finds no task)."""
        self._stopping = True
        if self._wake is not None:
            self._wake.set()
        task, self._task = self._task, None
        if task is not None:
            await task

    async def halt(self) -> None:
        """Stop the pump after the in-flight segment WITHOUT draining:
        unfinished requests stay live/queued in the core so a follow-up
        ``core.shutdown(checkpoint=True)`` can journal + checkpoint them
        for restart recovery. This is the SIGTERM graceful-drain path."""
        self._halt = True
        await self.stop()

    async def _loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            if self._halt:
                break
            if self.core.idle:
                if self._stopping:
                    break
                self._wake.clear()
                self._parked = True
                await self._wake.wait()
                self._parked = False
                continue
            events, dones = await loop.run_in_executor(None, self.core.step)
            for uid, toks in events:
                q = self._streams.get(uid)
                if q is not None:
                    for t in toks:
                        q.put_nowait(t)
            for comp in dones:
                # prune the per-request maps as the request completes —
                # a long-lived server must not grow per-uid state forever
                q = self._streams.pop(uid := comp.uid, None)
                if q is not None:
                    q.put_nowait(self._DONE)
                fut = self._futures.pop(uid, None)
                if fut is not None and not fut.done():
                    fut.set_result(comp)
                self._remember(uid, comp)
            await asyncio.sleep(0)
