"""Crash-safe serving: write-ahead request journal, bit-exact pool
checkpoints, and deterministic restart recovery.

Lethe makes mid-generation KV state *expensive to lose*: after a crash a
request can only be rebuilt by re-prefilling and re-decoding every emitted
token, and stateful policies (LazyEviction's armed/observing phase carried
in ``(budget, evict_at)``) make the live cache the only authoritative copy
of pruning state. This module is the durability layer over the primitives
the serving stack already proved bit-exact:

* **Write-ahead journal** (``Journal``) — an append-only, fsync'd JSONL of
  request lifecycle events: ``submit`` (prompt + knobs) → ``admit`` →
  per-segment ``tok`` records carrying an *absolute token offset* (the
  emission watermark) → exactly one ``end`` terminal. Every line carries a
  blake2b checksum; a torn tail (the line a SIGKILL interrupted) is
  detected on read and truncated before the journal is appended again.
  The journal is appended BEFORE tokens become client-visible, so the
  watermark always covers everything a client may have seen.

* **Pool checkpoints** (``write_checkpoint``/``load_checkpoint``) — the
  live slots (plus any preempted host snapshots) serialized from
  ``cache.extract_slots`` rows through the bit-exact pack in
  ``checkpoint/ckpt.py``, written atomically (tmp dir + rename; a crash
  mid-write leaves no ``ckpt-*`` entry). The manifest is fingerprinted by
  the PR-7 ``prefix_fingerprint`` (policy knobs + ``kv_format`` + cache
  dtype + arch + mesh ``topology_token()``), so a checkpoint can never
  restore under an incompatible layout — recovery then falls back to
  journal replay.

* **Recovery** (``recover``) — replays the journal against the newest
  compatible checkpoint: snapshotted rows re-enter the pool through the
  preemption ``insert_slots`` path (resuming mid-generation bit-exactly),
  admitted-but-unsnapshotted rows fall back to re-prefill (probing the
  prefix store when one is attached), and the emission watermark makes
  token emission at-most-once: regenerated tokens below the watermark are
  recomputed (bit-identical, the snapshot/differential guarantee) but
  never re-emitted or re-journaled. Terminals are exactly-once: a uid with
  an ``end`` record is never requeued.

``SimulatedCrash`` + ``Durability.crash_points`` give the kill-point test
harness deterministic crash injection at the boundaries that matter
(after-admit, mid-segment, after-harvest-before-journal-append,
mid-checkpoint) without having to race a real SIGKILL. DESIGN.md
§Durability documents the format and the recovery semantics;
``benchmarks/crash_recovery.py`` measures restore-vs-replay.
"""
from __future__ import annotations

import glob
import hashlib
import json
import os
import shutil
from dataclasses import dataclass, field

import numpy as np

from repro.checkpoint import ckpt

JOURNAL_NAME = "journal.log"
_CKPT_PREFIX = "ckpt-"


class SimulatedCrash(RuntimeError):
    """Raised by an armed crash point: the kill-point harness's stand-in
    for SIGKILL. Only raised when a test arms ``Durability.crash_points``;
    production runs never see it."""


@dataclass
class DurabilityConfig:
    root: str                      # directory for journal + checkpoints
    fsync: bool = True             # fsync every journal append
    checkpoint_every: int = 8      # boundaries between pool checkpoints
    keep_checkpoints: int = 2      # completed checkpoints retained on disk


def _line(rec: dict) -> str:
    body = json.dumps(rec, separators=(",", ":"), sort_keys=True)
    c = hashlib.blake2b(body.encode(), digest_size=4).hexdigest()
    return f"{body} #{c}\n"


def _parse_line(line: str) -> dict | None:
    """One journal line -> record, or None when torn/corrupt (bad JSON,
    bad checksum, or missing trailing newline)."""
    if not line.endswith("\n"):
        return None
    try:
        body, c = line.rstrip("\n").rsplit(" #", 1)
    except ValueError:
        return None
    if hashlib.blake2b(body.encode(), digest_size=4).hexdigest() != c:
        return None
    try:
        return json.loads(body)
    except json.JSONDecodeError:
        return None


def read_journal(path: str) -> tuple[list[dict], int]:
    """Read every intact record; returns (records, good_bytes) where
    ``good_bytes`` is the byte offset of the first torn/corrupt line (==
    file size for a clean journal). Everything past the first bad line is
    ignored — the journal is append-only, so a corrupt line means the
    crash interrupted that append and nothing after it was written."""
    records: list[dict] = []
    good = 0
    if not os.path.exists(path):
        return records, good
    with open(path, "rb") as f:
        for raw in f:
            rec = _parse_line(raw.decode("utf-8", errors="replace"))
            if rec is None:
                break
            records.append(rec)
            good += len(raw)
    return records, good


class Journal:
    """Append-only fsync'd journal writer. ``append`` is write-ahead: it
    returns only after the line is on disk (when ``fsync``), so any event
    the serving loop acts on is durable first."""

    def __init__(self, path: str, *, fsync: bool = True):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.path = path
        self.fsync = fsync
        self._f = open(path, "a", encoding="utf-8")
        self.n_appends = 0

    def append(self, rec: dict) -> None:
        self._f.write(_line(rec))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.n_appends += 1

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()


# --------------------------------------------------------------------------
# Pool checkpoints
# --------------------------------------------------------------------------

@dataclass
class Checkpoint:
    """A loaded pool checkpoint: per-uid snapshot rows + decode cursors."""
    seq: int
    fingerprint: str               # hex of the prefix_fingerprint bytes
    uids: list[int]
    rows: object                   # packed tree, batch axis = len(uids)
    tok: dict[int, int]            # uid -> last emitted token
    pos: dict[int, int]            # uid -> next decode position
    n_tokens: dict[int, int]       # uid -> tokens generated at snapshot

    def row_for(self, uid: int):
        """Single-row (batch axis 1) slice for one uid — exactly the
        ``rows_state`` shape ``cache.insert_slots`` re-admits."""
        import jax
        j = self.uids.index(uid)
        return jax.tree.map(lambda x: np.asarray(x)[:, j:j + 1], self.rows)


def _ckpt_dir(root: str, seq: int) -> str:
    return os.path.join(root, f"{_CKPT_PREFIX}{seq:06d}")


def list_checkpoints(root: str) -> list[int]:
    out = []
    for d in glob.glob(os.path.join(root, f"{_CKPT_PREFIX}*")):
        if os.path.isfile(os.path.join(d, "manifest.json")):
            try:
                out.append(int(os.path.basename(d)[len(_CKPT_PREFIX):]))
            except ValueError:
                continue
    return sorted(out)


def write_checkpoint(root: str, seq: int, fingerprint: bytes,
                     entries: list[tuple[int, object, int, int, int]], *,
                     keep: int = 2, crash=None) -> str:
    """Atomically write checkpoint ``seq``: ``entries`` is a list of
    (uid, rows with batch axis 1, last_token, next_pos, n_tokens). Rows
    are concatenated along the batch axis and packed bit-exactly; the
    manifest (written last, inside a tmp dir renamed into place) is what
    makes a checkpoint visible — a crash at any earlier point leaves only
    an ignored ``.tmp-*`` directory. Old checkpoints beyond ``keep`` are
    pruned AFTER the new one commits."""
    import jax
    tmp = os.path.join(root, f".tmp-{seq:06d}")
    final = _ckpt_dir(root, seq)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    uids = [int(u) for u, *_ in entries]
    if entries:
        rows = jax.tree.map(
            lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=1),
            *[r for _, r, *_ in entries])
    else:
        rows = {}
    arrays, meta = ckpt.pack_bitexact(rows)
    np.savez(os.path.join(tmp, "rows.npz"), **arrays)
    if crash is not None:
        crash("mid_checkpoint")      # rows on disk, manifest missing
    manifest = {
        "seq": seq,
        "fingerprint": fingerprint.hex(),
        "uids": uids,
        "tok": [int(t) for _, _, t, _, _ in entries],
        "pos": [int(p) for _, _, _, p, _ in entries],
        "n_tokens": [int(n) for _, _, _, _, n in entries],
        "rows_meta": meta,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final) if not os.path.exists(final) else None
    # prune superseded checkpoints (never the one just written)
    for old in list_checkpoints(root)[:-keep] if keep else []:
        if old != seq:
            shutil.rmtree(_ckpt_dir(root, old), ignore_errors=True)
    return final


def load_checkpoint(root: str, seq: int, donor_row) -> Checkpoint:
    """Load checkpoint ``seq``; ``donor_row`` is a single-row extract of a
    fresh decode state under the SAME engine config (structure/dtype
    donor for the bit-exact unpack)."""
    d = _ckpt_dir(root, seq)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    uids = manifest["uids"]
    if uids:
        with np.load(os.path.join(d, "rows.npz")) as data:
            rows = ckpt.unpack_bitexact(dict(data), manifest["rows_meta"],
                                        donor_row)
    else:
        rows = {}
    return Checkpoint(
        seq=manifest["seq"], fingerprint=manifest["fingerprint"],
        uids=uids, rows=rows,
        tok=dict(zip(uids, manifest["tok"])),
        pos=dict(zip(uids, manifest["pos"])),
        n_tokens=dict(zip(uids, manifest["n_tokens"])))


def latest_compatible_checkpoint(root: str, fingerprint: bytes,
                                 donor_row) -> Checkpoint | None:
    """Newest checkpoint whose manifest fingerprint matches the CURRENT
    engine's — an incompatible one (different policy knobs, kv_format, or
    mesh topology) is skipped, not coerced: recovery then falls back to
    journal replay for its rows."""
    for seq in reversed(list_checkpoints(root)):
        d = _ckpt_dir(root, seq)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if manifest["fingerprint"] == fingerprint.hex():
            return load_checkpoint(root, seq, donor_row)
    return None


# --------------------------------------------------------------------------
# The runtime object the front door drives
# --------------------------------------------------------------------------

class Durability:
    """Journal + checkpoint driver bound to one serving run directory.

    The front door calls the ``log_*`` hooks at each lifecycle transition
    (each append is durable before the event becomes client-visible) and
    ``maybe_checkpoint``/``write_pool_checkpoint`` at segment boundaries.
    ``crash_points`` is the kill-point harness hook: arming a point name
    makes the matching ``crash()`` call raise ``SimulatedCrash`` exactly
    once, emulating a SIGKILL at that boundary."""

    def __init__(self, cfg: DurabilityConfig | str):
        if isinstance(cfg, str):
            cfg = DurabilityConfig(root=cfg)
        self.cfg = cfg
        os.makedirs(cfg.root, exist_ok=True)
        self.journal = Journal(os.path.join(cfg.root, JOURNAL_NAME),
                               fsync=cfg.fsync)
        seqs = list_checkpoints(cfg.root)
        self._next_seq = (seqs[-1] + 1) if seqs else 1
        self._boundaries = 0
        self.sealed = False
        # telemetry
        self.n_checkpoints = 0
        self.n_tokens_logged = 0
        self.checkpoint_seconds: list[float] = []
        # kill-point harness: arm a point name to crash there (once)
        self.crash_points: set[str] = set()

    # ---- crash injection --------------------------------------------------

    def crash(self, point: str) -> None:
        if point in self.crash_points:
            self.crash_points.discard(point)
            raise SimulatedCrash(point)

    # ---- journal events ---------------------------------------------------

    def log_open(self, fingerprint: bytes) -> None:
        self.journal.append({"ev": "open", "fp": fingerprint.hex()})

    def log_submit(self, req) -> None:
        self.journal.append({
            "ev": "submit", "uid": int(req.uid),
            "prompt": [int(t) for t in np.asarray(req.prompt).reshape(-1)],
            "n": int(req.max_new_tokens), "pri": int(req.priority),
            "dl": req.deadline_s, "dt": req.decode_timeout_s})

    def log_admit(self, uid: int) -> None:
        self.journal.append({"ev": "admit", "uid": int(uid)})

    def log_tokens(self, uid: int, off: int, toks: list[int]) -> None:
        if not toks:
            return
        self.journal.append({"ev": "tok", "uid": int(uid), "off": int(off),
                             "toks": [int(t) for t in toks]})
        self.n_tokens_logged += len(toks)

    def log_terminal(self, uid: int, reason: str,
                     detail: str | None = None) -> None:
        self.journal.append({"ev": "end", "uid": int(uid), "reason": reason,
                             "detail": detail})

    def log_recover(self, n_resumed: int, n_replayed: int) -> None:
        self.journal.append({"ev": "recover", "resumed": n_resumed,
                             "replayed": n_replayed})

    def seal(self) -> None:
        """Graceful-shutdown marker: every non-terminal uid before the seal
        is intentionally outstanding (checkpointed or queued), not lost."""
        if not self.sealed:
            self.journal.append({"ev": "seal"})
            self.sealed = True
        self.journal.close()

    # ---- checkpoints ------------------------------------------------------

    def checkpoint_due(self) -> bool:
        self._boundaries += 1
        return (self.cfg.checkpoint_every > 0
                and self._boundaries % self.cfg.checkpoint_every == 0)

    def write_pool_checkpoint(self, fingerprint: bytes, entries) -> int:
        import time
        t0 = time.perf_counter()
        seq = self._next_seq
        write_checkpoint(self.cfg.root, seq, fingerprint, entries,
                         keep=self.cfg.keep_checkpoints, crash=self.crash)
        self._next_seq += 1
        self.n_checkpoints += 1
        self.checkpoint_seconds.append(time.perf_counter() - t0)
        return seq

    def stats(self) -> dict:
        return {
            "journal_appends": self.journal.n_appends,
            "tokens_logged": self.n_tokens_logged,
            "checkpoints_written": self.n_checkpoints,
            "last_checkpoint_seq": self._next_seq - 1,
            "checkpoint_seconds_mean": (
                float(np.mean(self.checkpoint_seconds))
                if self.checkpoint_seconds else 0.0),
            "sealed": self.sealed,
        }


# --------------------------------------------------------------------------
# Journal digest + recovery
# --------------------------------------------------------------------------

@dataclass
class JournalDigest:
    """Per-uid fold of a journal: what was promised (submit), what was
    durably emitted (the token watermark), and what terminated."""
    requests: dict[int, dict] = field(default_factory=dict)
    order: list[int] = field(default_factory=list)       # submit order
    admitted: set[int] = field(default_factory=set)
    tokens: dict[int, list[int]] = field(default_factory=dict)
    terminal: dict[int, tuple[str, str | None]] = field(default_factory=dict)
    sealed: bool = False

    def outstanding(self) -> list[int]:
        return [u for u in self.order if u not in self.terminal]

    def watermark(self, uid: int) -> int:
        return len(self.tokens.get(uid, []))


def digest_journal(records: list[dict]) -> JournalDigest:
    d = JournalDigest()
    for r in records:
        ev = r["ev"]
        if ev == "submit":
            uid = r["uid"]
            if uid not in d.requests:
                d.order.append(uid)
            d.requests[uid] = r
        elif ev == "admit":
            d.admitted.add(r["uid"])
        elif ev == "tok":
            lst = d.tokens.setdefault(r["uid"], [])
            off, toks = r["off"], r["toks"]
            if off > len(lst):          # gap cannot happen in a valid log
                raise ValueError(
                    f"journal token gap for uid {r['uid']}: "
                    f"offset {off} past watermark {len(lst)}")
            lst[off:off + len(toks)] = toks
        elif ev == "end":
            d.terminal[r["uid"]] = (r["reason"], r.get("detail"))
        elif ev == "seal":
            d.sealed = True
    return d


def recover(engine, root: str, *, batch_slots: int,
            durability: "Durability | DurabilityConfig | str | None" = None,
            **core_kw):
    """Rebuild a ``FrontDoorCore`` from the journal + newest compatible
    checkpoint in ``root``. Returns (core, report).

    * torn journal tail -> truncated, then the journal is re-opened for
      appending (the recovered core keeps writing the same stream; token
      offsets are absolute, so the watermark survives any number of
      crashes);
    * uids with a terminal -> skipped (exactly-once terminal);
    * snapshotted uids under a matching fingerprint -> queued holding
      their checkpoint rows; admission re-enters them through the
      preemption ``insert_slots`` path (no prefill);
    * everything else outstanding -> queued cold; admission re-prefills
      (through the prefix store when one is attached and hits);
    * every recovered uid carries its emission watermark: regenerated
      tokens below it are recomputed bit-exactly but never re-emitted or
      re-journaled (at-most-once emission).
    """
    from repro.core import cache as cache_lib
    from repro.serving.frontdoor import FrontDoorCore, ServeRequest, _Entry
    from repro.serving.scheduler import PREEMPTED, QUEUED

    jpath = os.path.join(root, JOURNAL_NAME)
    records, good = read_journal(jpath)
    torn = (os.path.getsize(jpath) - good if os.path.exists(jpath) else 0)
    if torn:
        with open(jpath, "r+b") as f:     # drop the torn tail before we
            f.truncate(good)              # ever append again
    dig = digest_journal(records)

    if durability is None:
        durability = DurabilityConfig(root=root)
    core = FrontDoorCore(engine, batch_slots, durability=durability,
                         **core_kw)
    dur = core.dur

    donor = cache_lib.extract_slots(engine.new_decode_state(1), [0])
    ck = latest_compatible_checkpoint(root, core._fp, donor)

    n_resumed = n_replayed = 0
    now = core.clock()
    for uid in dig.outstanding():
        r = dig.requests[uid]
        req = ServeRequest(
            uid=uid, prompt=np.asarray(r["prompt"], np.int32),
            max_new_tokens=r["n"], priority=r.get("pri", 0),
            deadline_s=r.get("dl"), decode_timeout_s=r.get("dt"))
        core._seq += 1
        e = _Entry(req=req, submit_ts=now, seq=core._seq,
                   queue_depth=len(core.queue))
        w = dig.watermark(uid)
        e.emit_from = w
        e.journaled = w
        if ck is not None and uid in ck.tok:
            n = ck.n_tokens[uid]
            e.tokens = list(dig.tokens.get(uid, [])[:n])
            e.snapshot = (ck.row_for(uid), ck.tok[uid], ck.pos[uid])
            core.lifecycle[uid] = [QUEUED, PREEMPTED]
            n_resumed += 1
        else:
            e.tokens = []                 # cold: re-prefill + re-decode
            core.lifecycle[uid] = [QUEUED]
            n_replayed += 1
        core.queue.append(e)
    dur.log_recover(n_resumed, n_replayed)

    report = {
        "journal_records": len(records),
        "journal_truncated_bytes": torn,
        "sealed": dig.sealed,
        "terminals": len(dig.terminal),
        "outstanding": len(dig.outstanding()),
        "known_uids": sorted(dig.requests),
        "resumed_from_checkpoint": n_resumed,
        "replayed_from_prompt": n_replayed,
        "checkpoint_seq": ck.seq if ck is not None else None,
        # The output-commit record: tokens the journal proves durable per
        # uid (offset-addressed). A token can be fsync'd and then lost on
        # the wire when the crash lands between the append and the client
        # write — the serving shell replays these to a reconnecting client
        # from its acknowledged offset, which is what turns the core's
        # at-most-once emission into an exactly-once client stream.
        "durable_tokens": {u: list(t) for u, t in dig.tokens.items()},
        "finished": {u: r for u, (r, _) in dig.terminal.items()},
    }
    return core, report
