"""Figure 4 reproduction: latency / generation memory / throughput vs
generated-token count. The paper shows FullKV latency+memory growing with
length while Lethe plateaus after the first pruning rounds."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.serving.engine import Engine


def run(csv: common.CsvOut) -> None:
    model, params = common.train_model("reasoning")
    seq0 = 64
    rng = np.random.default_rng(1)
    toks = rng.integers(0, model.cfg.vocab_size, size=(2, seq0)).astype(
        np.int32)
    for kind in ("fullkv", "lethe"):
        for gen in (64, 128, 256):
            cap = seq0 + gen + 8 if kind == "fullkv" else 48
            pol = common.make_policy_for(kind, cap)
            eng = Engine(model, params, pol)
            res = eng.generate({"tokens": jnp.asarray(toks)}, gen,
                               trace_live=True)
            live_end = (res.live_token_trace[-1]
                        if res.live_token_trace else 0)
            csv.add(f"fig4/{kind}/gen{gen}",
                    res.decode_seconds * 1e6 / (2 * gen),
                    f"decode_s={res.decode_seconds:.2f};"
                    f"cache_mb={res.cache_bytes/2**20:.2f};"
                    f"live_tokens_final={live_end};"
                    f"tput={res.tokens_per_second:.1f}")
