"""Shared benchmark infrastructure: trained tiny models (cached on disk),
the policy grid, and CSV emission.

Benchmarks reproduce the *shape* of every paper table at CPU scale (DESIGN.md
§Faithfulness): same policy grid {FullKV, H2O, StreamingLLM, PyramidKV,
Lethe}, same metric families (task accuracy, latency, peak cache memory,
tokens/s), on models trained in-framework on synthetic reasoning workloads.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.core.policy import PolicyConfig, make_policy
from repro.data import pipeline
from repro.launch import steps
from repro.models.api import ModelAPI, build_model
from repro.optim import adamw

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")

# Task sizes chosen so a 4-layer d=128 model trained for ~1200 CPU steps
# reaches well-above-chance accuracy (CPU-scale stand-ins for Math500/MMLU;
# what matters is the relative ordering across the policy grid).
REASONING = pipeline.ReasoningConfig(n_values=16, n_steps=16, batch_size=24)
RECALL = pipeline.RecallConfig(n_values=16, n_pairs=4, filler_steps=12,
                               n_queries=4, batch_size=24)
TRAIN_STEPS = {"reasoning": 1200, "recall": 1200}

POLICY_GRID = ("fullkv", "h2o", "streaming", "pyramidkv", "lethe")
# The paper grid plus the decode-time eviction rivals (LazyEviction, G-KV):
# the quality regression surface benchmarks/policy_quality.py sweeps.
PRUNING_FAMILIES = ("h2o", "streaming", "pyramidkv", "lethe",
                    "lazyeviction", "gkv")


def bench_arch(vocab_size: int):
    """Tiny llama-family config for CPU benchmarking."""
    return dataclasses.replace(
        get_arch("granite-20b").reduced(),
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab_size=vocab_size)


def make_policy_for(kind: str, capacity: int, **kw) -> PolicyConfig:
    # gamma/sparse_ratio tuned on the recall task (see EXPERIMENTS.md):
    # aggressive decay (gamma=0.95) forgets long-range keys; near-1 decay
    # approaches H2O. 0.995/τ=20 balances CoT recency vs recall retention.
    # H2O and G-KV accumulate undecayed mass (γ=1; G-KV age-normalises at
    # decide time instead of decaying).
    kw.setdefault("sink_len", 4)
    kw.setdefault("sparse_ratio", 20.0)
    kw.setdefault("recent_ratio", 0.3)
    kw.setdefault("target_fill", 0.6)
    kw.setdefault("gamma", 1.0 if kind in ("h2o", "gkv") else 0.995)
    kw.setdefault("lag_window", max(8, capacity // 4))
    return make_policy(kind, capacity=capacity, **kw)


def train_model(task: str = "reasoning", steps_n: int | None = None,
                force: bool = False) -> tuple[ModelAPI, dict]:
    """Train (or load cached) tiny model on the named synthetic task."""
    steps_n = steps_n or TRAIN_STEPS[task]
    dcfg = REASONING if task == "reasoning" else RECALL
    cfg = bench_arch(dcfg.vocab_size)
    model = build_model(cfg)
    path = os.path.join(CACHE_DIR, f"bench_model_{task}")
    params = model.init(jax.random.PRNGKey(0))
    if not force and os.path.exists(path + ".npz"):
        return model, ckpt.restore(path, params)

    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=30,
                                total_steps=steps_n)
    train_step = jax.jit(steps.make_train_step(model, opt_cfg))
    opt_state = adamw.init(params)
    make_batch = (pipeline.reasoning_batch if task == "reasoning"
                  else pipeline.recall_batch)
    t0 = time.time()
    for i in range(steps_n):
        b = make_batch(dcfg, i)
        batch = {"tokens": b["tokens"], "loss_weights": b["loss_weights"]}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if i % 100 == 0:
            print(f"  [train:{task}] step {i} loss={float(metrics['loss']):.3f}")
    print(f"  [train:{task}] done in {time.time()-t0:.0f}s "
          f"final loss={float(metrics['loss']):.3f}")
    ckpt.save(path, params, step=steps_n)
    return model, params


def teacher_forced_decode(model: ModelAPI, params, policy: PolicyConfig,
                          tokens: jax.Array, prefill_len: int) -> jax.Array:
    """Prefill the prompt head, then decode the rest teacher-forced through
    the (pruned) cache — the paper's CoT-generation regime, where the cache
    grows during decode and multi-round pruning fires. Returns logits
    predicting positions [prefill_len, S) — entry t predicts tokens[:, t].
    """
    B, S = tokens.shape
    logits0, state = model.prefill(
        params, {"tokens": tokens[:, :prefill_len]}, policy)

    def step(carry, t):
        state = carry
        logits, state = model.module.decode_step(
            params, state, tokens[:, t], t, model.cfg, policy)
        return state, logits

    @jax.jit
    def run(state):
        _, logits = jax.lax.scan(
            step, state,
            jnp.arange(prefill_len, S - 1, dtype=jnp.int32))
        return logits                         # [S-1-prefill_len, B, V]

    logits = run(state)
    # prepend prefill's last-token logits (predicts position prefill_len)
    return jnp.concatenate([logits0[None], logits], axis=0)


def eval_answer_accuracy(model: ModelAPI, params, policy: PolicyConfig,
                         task: str, n_batches: int = 2,
                         seed0: int = 10_000) -> dict:
    """Teacher-forced decode through the whole CoT under ``policy``; compare
    argmax predictions at every answer position. Also returns the answer-
    position log-probs for KL-vs-FullKV."""
    dcfg = REASONING if task == "reasoning" else RECALL
    make_batch = (pipeline.reasoning_batch if task == "reasoning"
                  else pipeline.recall_batch)
    correct = total = 0
    t0 = time.time()
    logits_all = []
    for i in range(n_batches):
        b = make_batch(dcfg, seed0 + i)
        toks = b["tokens"]
        p0 = int(b["prefill_len"])
        logits = teacher_forced_decode(model, params, policy, toks, p0)
        for j, ap in enumerate(b["answer_positions"]):
            lg = logits[int(ap) - p0]                     # [B, V]
            pred = jnp.argmax(lg, -1)
            correct += int(jnp.sum(pred == b["answers"][:, j]))
            total += int(b["answers"].shape[0])
            logits_all.append(np.asarray(jax.nn.log_softmax(lg)))
    return {"accuracy": correct / total, "n": total,
            "seconds": time.time() - t0,
            "logits": np.concatenate(logits_all)}


def kl_vs_reference(logp: np.ndarray, logp_ref: np.ndarray) -> float:
    p_ref = np.exp(logp_ref)
    return float(np.mean(np.sum(p_ref * (logp_ref - logp), axis=-1)))


def device_topology(mesh=None) -> dict:
    """Device/mesh identity for benchmark config blocks: every BENCH_*.json
    records what hardware layout produced it (a single-device CPU run and
    an 8-fake-device mesh run are not comparable rows).

    ``mesh``: a ``repro.serving.meshing.ServingMesh`` (its axes are
    recorded) or None (flat device list)."""
    if mesh is not None:
        return mesh.topology()
    devs = jax.devices()
    return {"axes": None, "n_devices": len(devs),
            "platform": devs[0].platform}


def merge_json_section(path: str, key: str, value) -> None:
    """Set one top-level section of a benchmark JSON, preserving the other
    sections (e.g. BENCH_kv_quant.json's ``kernel``/``serving`` halves are
    written by different benchmark entry points)."""
    import json
    os.makedirs(os.path.dirname(path), exist_ok=True)
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged[key] = value
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)


class CsvOut:
    """Collects ``name,us_per_call,derived`` rows for benchmarks/run.py."""
    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}")

    def dump(self, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in self.rows:
                f.write(f"{n},{u:.1f},{d}\n")
