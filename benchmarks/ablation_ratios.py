"""Tables 5–6 reproduction: sparse_ratio (τ) and recent_ratio ablations —
accuracy + cache memory per setting, expecting the paper's pattern
(diminishing returns in τ; a sweet spot near recent_ratio=0.3)."""
from __future__ import annotations

import dataclasses
import time

from benchmarks import common


def run(csv: common.CsvOut) -> None:
    task = "recall"
    model, params = common.train_model(task)
    seq = common.RECALL.seq_len
    cap = max(16, int(seq * 0.4))

    base = common.make_policy_for("lethe", cap)
    for tau in (1.2, 2.0, 4.0, 10.0, 100.0):   # paper: 20..1000
        pol = dataclasses.replace(base, sparse_ratio=tau)
        t0 = time.time()
        r = common.eval_answer_accuracy(model, params, pol, task,
                                        n_batches=3)
        csv.add(f"ablation/sparse_ratio/{tau}",
                (time.time() - t0) * 1e6 / r["n"],
                f"acc={r['accuracy']:.3f};capacity={cap}")

    for rr in (0.1, 0.2, 0.3, 0.4):
        pol = dataclasses.replace(base, recent_ratio=rr)
        t0 = time.time()
        r = common.eval_answer_accuracy(model, params, pol, task,
                                        n_batches=3)
        csv.add(f"ablation/recent_ratio/{rr}",
                (time.time() - t0) * 1e6 / r["n"],
                f"acc={r['accuracy']:.3f};capacity={cap}")
