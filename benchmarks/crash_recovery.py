"""Crash recovery: snapshot restore vs journal replay, and what the WAL
costs while nothing is crashing.

Two measurements a durability claim needs (DESIGN.md §Durability):

* **Recovery-vs-replay curve** — crash the same durable run at several
  generation-progress fractions, then recover twice from the same
  journal: once WITH the pool checkpoints (snapshotted rows re-enter
  through ``insert_slots``) and once with the checkpoints withheld
  (every outstanding row re-prefills and regenerates its suppressed
  prefix). The metric is *time until every outstanding request emits its
  first fresh token* — the client-visible recovery gap. The later the
  crash, the more tokens replay has to regenerate, so the snapshot
  speedup grows with progress; the acceptance bar is >= 3x at the latest
  crash point (asserted on the full run).
* **Checkpoint overhead** — the same traffic with durability off vs on
  (fsync'd journal + periodic checkpoints): wall-time overhead fraction
  and per-checkpoint write cost. This is the row to read against
  ``BENCH_serving_traffic.json``'s uninstrumented continuous-batching
  numbers.

Both recovered streams are asserted bitwise identical to the undisturbed
baseline before any timing is reported — a fast recovery of wrong tokens
is not a recovery.

Emits ``experiments/BENCH_crash_recovery.json``. Standalone:
    PYTHONPATH=src python benchmarks/crash_recovery.py [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks import common
from repro.models.api import build_model
from repro.serving import durability as dur_lib
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, FrontDoorCore,
                                     ServeRequest)

INF = float("inf")


def _requests(n: int, prompt_len: int, max_new: int, vocab: int,
              seed: int = 0) -> list[ServeRequest]:
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        uid=i,
        prompt=rng.integers(0, vocab, size=prompt_len).astype(np.int32),
        max_new_tokens=max_new)
        for i in range(n)]


def _transparent() -> AdmissionConfig:
    return AdmissionConfig(compress_at=INF, shed_at=INF, reject_at=INF)


def _drain(core, streams=None):
    while not core.idle:
        ev, _ = core.step()
        if streams is not None:
            for uid, toks in ev:
                streams.setdefault(uid, []).extend(toks)
    return {c.uid: list(c.tokens) for c in core.completed}


def _run_traffic(eng, reqs, *, slots, segment_len, durability=None):
    """Closed-loop drain; returns (wall_s, {uid: tokens}, summary)."""
    core = FrontDoorCore(eng, batch_slots=slots, segment_len=segment_len,
                         admission=_transparent(), durability=durability)
    core.submit(reqs)
    t0 = time.perf_counter()
    out = _drain(core)
    return time.perf_counter() - t0, out, core.run_summary()


def _crash_at_fraction(eng, reqs, root, frac, total_tokens, *, slots,
                       segment_len, ckpt_every):
    """Durable run crashed (SimulatedCrash at the next segment boundary)
    once ``frac`` of the workload's tokens have been generated."""
    d = dur_lib.Durability(dur_lib.DurabilityConfig(
        root=root, checkpoint_every=ckpt_every))
    core = FrontDoorCore(eng, batch_slots=slots, segment_len=segment_len,
                         admission=_transparent(), durability=d)
    core.submit(reqs)
    produced = 0
    try:
        while not core.idle:
            ev, _ = core.step()
            produced += sum(len(t) for _, t in ev)
            if produced >= frac * total_tokens:
                d.crash_points.add("after_harvest")
    except dur_lib.SimulatedCrash:
        pass
    assert dur_lib.list_checkpoints(root), \
        "crash landed before any pool checkpoint committed"
    return produced


def _timed_recovery(eng, root, base, *, slots, segment_len) -> dict:
    """Recover and report the client-visible gap: wall until EVERY
    outstanding uid emits its first fresh (post-watermark) token, then
    drain and assert the assembled streams match the baseline bitwise."""
    t0 = time.perf_counter()
    core, report = dur_lib.recover(eng, root, batch_slots=slots,
                                   segment_len=segment_len,
                                   admission=_transparent())
    recover_call_s = time.perf_counter() - t0
    outstanding = {u for u in base
                   if u not in report["finished"]}
    streams = {u: list(t) for u, t in report["durable_tokens"].items()}
    waiting = set(outstanding)
    first_fresh_s = None
    while not core.idle:
        ev, _ = core.step()
        for uid, toks in ev:
            streams.setdefault(uid, []).extend(toks)
            waiting.discard(uid)
        if not waiting and first_fresh_s is None:
            first_fresh_s = time.perf_counter() - t0
    for c in core.completed:        # finished while queued (edge): count
        waiting.discard(c.uid)
    total_s = time.perf_counter() - t0
    if first_fresh_s is None:
        first_fresh_s = total_s
    for u, toks in base.items():    # correctness before timing is quoted
        np.testing.assert_array_equal(
            streams.get(u, []), toks,
            err_msg=f"recovered stream diverged for uid {u}")
    return {
        "recover_call_s": recover_call_s,
        "time_to_all_fresh_s": first_fresh_s,
        "total_s": total_s,
        "resumed_from_checkpoint": report["resumed_from_checkpoint"],
        "replayed_from_prompt": report["replayed_from_prompt"],
        "outstanding": report["outstanding"],
    }


def benchmark(*, tiny: bool = False, out_path: str | None = None,
              csv: common.CsvOut | None = None) -> dict:
    # single wave (n_req == slots): every request stays live from admit to
    # crash, so checkpoints always hold the full pool and the staleness
    # gap resume must regenerate is bounded by ckpt_every segments — the
    # clean contrast against replay's frac*max_new regeneration
    if tiny:
        cfg, capacity = common.bench_arch(512), 32
        slots, segment_len, prompt_len, max_new, n_req = 2, 4, 16, 32, 2
        fracs = (0.5, 0.75)
    else:
        cfg, capacity = common.bench_arch(512), 64
        slots, segment_len, prompt_len, max_new, n_req = 4, 8, 32, 96, 4
        fracs = (0.25, 0.5, 0.75)
    ckpt_every = 2

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = common.make_policy_for("lethe", capacity)
    eng = Engine(model, params, pol)
    reqs = _requests(n_req, prompt_len, max_new, cfg.vocab_size)
    total_tokens = n_req * max_new

    work = tempfile.mkdtemp(prefix="bench_crash_")
    results: dict = {"config": {
        "device_topology": common.device_topology(),
        "tiny": tiny, "policy": "lethe", "capacity": capacity,
        "kv_format": pol.kv_format, "slots": slots,
        "segment_len": segment_len, "prompt_len": prompt_len,
        "max_new": max_new, "n_requests": n_req,
        "checkpoint_every": ckpt_every,
        "n_layers": cfg.n_layers, "d_model": cfg.d_model,
    }}
    try:
        # ---- checkpoint overhead: durability off vs on ------------------
        # (first run doubles as compile warmup; measure the second pair)
        _run_traffic(eng, reqs, slots=slots, segment_len=segment_len)
        plain_s, base, _ = _run_traffic(eng, reqs, slots=slots,
                                        segment_len=segment_len)
        dur_root = os.path.join(work, "overhead")
        dur_s, dur_out, dur_sum = _run_traffic(
            eng, reqs, slots=slots, segment_len=segment_len,
            durability=dur_lib.DurabilityConfig(root=dur_root,
                                                checkpoint_every=ckpt_every))
        for u, toks in base.items():
            np.testing.assert_array_equal(dur_out[u], toks)
        ds = dur_sum["durability"]
        results["checkpoint_overhead"] = {
            "plain_wall_s": plain_s,
            "durable_wall_s": dur_s,
            "overhead_frac": dur_s / max(plain_s, 1e-9) - 1.0,
            "plain_tok_s": total_tokens / max(plain_s, 1e-9),
            "durable_tok_s": total_tokens / max(dur_s, 1e-9),
            "journal_appends": ds["journal_appends"],
            "checkpoints_written": ds["checkpoints_written"],
            "checkpoint_mean_s": ds["checkpoint_seconds_mean"],
        }
        oh = results["checkpoint_overhead"]
        print(f"  [crash_recovery] WAL+checkpoint overhead: "
              f"{oh['overhead_frac'] * 100:.1f}% "
              f"({oh['durable_tok_s']:.1f} vs {oh['plain_tok_s']:.1f} "
              f"tok/s; {oh['checkpoints_written']} ckpts @ "
              f"{oh['checkpoint_mean_s'] * 1e3:.1f}ms)", flush=True)
        if csv is not None:
            csv.add("crash_recovery/overhead",
                    1e6 * oh["checkpoint_mean_s"],
                    f"overhead_frac={oh['overhead_frac']:.3f}")

        # ---- recovery-vs-replay curve -----------------------------------
        # warm BOTH recovery paths on a throwaway crash first: snapshot
        # resume compiles insert_slots + suppressed-resume programs on
        # first use, and charging that one-time cost to a timed cell
        # would make resume look slower than replay
        warm_root = os.path.join(work, "warm")
        _crash_at_fraction(eng, reqs, warm_root, fracs[0], total_tokens,
                           slots=slots, segment_len=segment_len,
                           ckpt_every=ckpt_every)
        warm_replay = os.path.join(work, "warm_replay")
        os.makedirs(warm_replay)
        shutil.copy(os.path.join(warm_root, dur_lib.JOURNAL_NAME),
                    os.path.join(warm_replay, dur_lib.JOURNAL_NAME))
        _timed_recovery(eng, warm_root, base, slots=slots,
                        segment_len=segment_len)
        _timed_recovery(eng, warm_replay, base, slots=slots,
                        segment_len=segment_len)

        results["recovery"] = {}
        for frac in fracs:
            root = os.path.join(work, f"crash{int(frac * 100)}")
            produced = _crash_at_fraction(
                eng, reqs, root, frac, total_tokens, slots=slots,
                segment_len=segment_len, ckpt_every=ckpt_every)
            # replay-root: same journal, checkpoints withheld — recovery
            # must fall back to re-prefill + watermark-suppressed decode
            replay_root = os.path.join(work, f"replay{int(frac * 100)}")
            os.makedirs(replay_root)
            shutil.copy(os.path.join(root, dur_lib.JOURNAL_NAME),
                        os.path.join(replay_root, dur_lib.JOURNAL_NAME))
            resume = _timed_recovery(eng, root, base, slots=slots,
                                     segment_len=segment_len)
            replay = _timed_recovery(eng, replay_root, base, slots=slots,
                                     segment_len=segment_len)
            assert resume["resumed_from_checkpoint"] > 0, resume
            assert replay["resumed_from_checkpoint"] == 0, replay
            speedup = (replay["time_to_all_fresh_s"]
                       / max(resume["time_to_all_fresh_s"], 1e-9))
            results["recovery"][f"{frac:g}"] = {
                "crash_fraction": frac,
                "tokens_before_crash": produced,
                "snapshot_resume": resume,
                "journal_replay": replay,
                "restore_speedup": speedup,
            }
            print(f"  [crash_recovery] crash@{frac:g}: resume "
                  f"{resume['time_to_all_fresh_s'] * 1e3:.0f}ms "
                  f"(resumed={resume['resumed_from_checkpoint']}) vs "
                  f"replay {replay['time_to_all_fresh_s'] * 1e3:.0f}ms "
                  f"-> {speedup:.1f}x", flush=True)
            if csv is not None:
                csv.add(f"crash_recovery/crash{frac:g}",
                        1e6 * resume["time_to_all_fresh_s"],
                        f"speedup={speedup:.2f}")
    finally:
        shutil.rmtree(work, ignore_errors=True)

    last = results["recovery"][f"{fracs[-1]:g}"]
    results["restore_speedup_at_latest_crash"] = last["restore_speedup"]
    if not tiny:
        # the durability claim: restoring a late-progress pool from its
        # snapshot beats regenerating it from the journal by >= 3x
        assert last["restore_speedup"] >= 3.0, last
    out_path = out_path or os.path.join(common.CACHE_DIR,
                                        "BENCH_crash_recovery.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"  [crash_recovery] wrote {out_path}", flush=True)
    return results


def run(csv: common.CsvOut) -> None:
    """benchmarks/run.py suite hook."""
    benchmark(tiny=False, csv=csv)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 2 crash points on the tiny bench arch")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = benchmark(tiny=args.tiny, out_path=args.out)
    print(f"restore speedup at latest crash point: "
          f"{res['restore_speedup_at_latest_crash']:.1f}x")


if __name__ == "__main__":
    main()
