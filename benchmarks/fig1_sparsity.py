"""Figure 1 reproduction: layerwise Hoyer attention-sparsity over decoding
steps. Dumps a layer×step heatmap CSV and checks the paper's qualitative
claims: sparsity varies across layers and evolves over time (non-pyramidal)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.policy import make_policy
from repro.data import pipeline


def run(csv: common.CsvOut) -> None:
    model, params = common.train_model("reasoning")
    dcfg = common.REASONING
    b = pipeline.reasoning_batch(dcfg, 777)
    pol = make_policy("fullkv", capacity=dcfg.seq_len + 80, sink_len=4)
    logits, state = model.prefill(params, {"tokens": b["tokens"][:, :40]},
                                  pol)
    tok = jnp.argmax(logits, -1)
    heat = []
    for t in range(48):
        logits, state = model.decode_step(params, state, tok,
                                          jnp.asarray(40 + t), pol)
        tok = jnp.argmax(logits, -1)
        # sparsity is per-row [L, B]; the Fig. 1 heatmap is the batch mean
        heat.append(np.asarray(state.sparsity).mean(axis=-1))
    heat = np.stack(heat)                       # [steps, layers]
    out = os.path.join(common.CACHE_DIR, "fig1_sparsity_heatmap.csv")
    np.savetxt(out, heat, delimiter=",",
               header=",".join(f"layer{i}" for i in range(heat.shape[1])))
    spread = float(heat[-1].max() - heat[-1].min())
    drift = float(np.abs(heat[-1] - heat[0]).mean())
    monotone = bool(np.all(np.diff(heat[-1]) >= -1e-3)
                    or np.all(np.diff(heat[-1]) <= 1e-3))
    csv.add("fig1/sparsity", 0.0,
            f"layer_spread={spread:.3f};temporal_drift={drift:.3f};"
            f"monotone_across_layers={monotone};csv={out}")
