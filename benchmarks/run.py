"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes it to
experiments/bench_results.csv.

Usage: PYTHONPATH=src python -m benchmarks.run [--only table1,table3]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (ablation_ratios, common, crash_recovery,
                        fig1_sparsity, fig4_scaling, kernels_micro,
                        serving_traffic, table1_accuracy, table2_memory,
                        table3_throughput)

SUITES = {
    "table1": table1_accuracy.run,
    "table2": table2_memory.run,
    "table3": table3_throughput.run,
    "fig1": fig1_sparsity.run,
    "fig4": fig4_scaling.run,
    "ablation": ablation_ratios.run,
    "kernels": kernels_micro.run,
    "serving": serving_traffic.run,
    "crash": crash_recovery.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    csv = common.CsvOut()
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        SUITES[name](csv)
        print(f"# {name} finished in {time.time()-t0:.0f}s", flush=True)
    out = os.path.join(common.CACHE_DIR, "bench_results.csv")
    csv.dump(out)
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
