"""SLO serving under open-loop Poisson arrivals: goodput vs offered load.

The curve a serving-robustness claim actually needs: requests arrive on
their own clock (open loop — the server falling behind does NOT slow the
arrival process), and the metric is **goodput** — requests that completed
healthily AND met their latency SLOs (TTFT and per-token ITL, thresholds
calibrated from an unloaded run) — as the offered load sweeps from below
saturation to several times above it.

Two front-door configurations run the same arrival trace at every load:

* ``robust``  — the full degradation ladder (compressed admission, load
  shedding, priority preemption) enabled;
* ``naive``   — shedding and preemption disabled: every arrival queues
  forever and is eventually served, long after its SLO expired.

Past saturation the naive queue grows without bound, so late requests' TTFT
explodes and SLO-goodput collapses toward zero; the robust door sheds the
unserveable backlog, keeping the requests it *does* serve inside their SLOs
— goodput plateaus at (roughly) the service capacity. That plateau-vs-
collapse shape is the acceptance criterion, asserted on the full run.

Emits ``experiments/BENCH_slo_serving.json``. Standalone:
    PYTHONPATH=src python benchmarks/slo_serving.py [--tiny]
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks import common
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.frontdoor import (AdmissionConfig, FrontDoor,
                                     FrontDoorCore, ServeRequest)

HEALTHY = ("eos", "length")


def _make_requests(n: int, prompt_len: int, max_new: int, vocab: int,
                   seed: int = 0) -> list[ServeRequest]:
    """70/30 priority mix at one prompt length (one prefill program): the
    mix is what gives preemption something to do under pressure."""
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        uid=i,
        prompt=rng.integers(0, vocab, size=prompt_len).astype(np.int32),
        max_new_tokens=max_new,
        priority=int(rng.random() < 0.3))
        for i in range(n)]


def _robust_admission() -> AdmissionConfig:
    # shed earlier than the library default: the bench's queues are short
    # (tens of requests), so overload must be recognisable within ~1.5
    # pool-fills of backlog for the sweep to show the ladder at all
    return AdmissionConfig(shed_at=1.5, reject_at=8.0,
                           enable_shed=True, enable_preempt=True)


def _naive_admission() -> AdmissionConfig:
    return AdmissionConfig(enable_shed=False, enable_preempt=False,
                           reject_at=float("inf"),
                           compress_at=float("inf"))


async def _drive_open_loop(fd: FrontDoor, reqs: list[ServeRequest],
                           gaps: list[float]) -> None:
    async def one(req, delay):
        await asyncio.sleep(delay)
        await fd.submit(req)

    t, tasks = 0.0, []
    for req, gap in zip(reqs, gaps):
        t += gap
        tasks.append(asyncio.ensure_future(one(req, t)))
    await asyncio.gather(*tasks)


def _run_load_point(eng_factory, reqs, gaps, adm, *, slots, segment_len
                    ) -> dict:
    """One (offered load, admission config) cell: fresh engine (fresh live
    state), open-loop arrivals, full drain; per-request latency stats."""
    eng = eng_factory()

    async def go():
        async with FrontDoor(eng, batch_slots=slots,
                             segment_len=segment_len, admission=adm) as fd:
            t0 = time.perf_counter()
            await _drive_open_loop(fd, reqs, gaps)
            await fd.drain()
            wall = time.perf_counter() - t0
            return fd.core, wall

    core, wall = asyncio.run(go())
    comps = sorted(core.completed, key=lambda c: c.uid)
    healthy = [c for c in comps if c.finish_reason in HEALTHY]
    ttft = [c.ttft_s for c in healthy]
    # per-token latency over the request's residency — the request-level
    # ITL a streaming client experiences (admit -> finish over tokens)
    itl = [1.0 / c.tokens_per_second for c in healthy
           if c.tokens_per_second > 0]
    # submit -> finish (queue wait + residency): the scale the TTFT SLO is
    # sized against, since any queueing at all dwarfs the unloaded TTFT
    e2e = [c.queue_wait_s + len(c.tokens) / c.tokens_per_second
           for c in healthy if c.tokens_per_second > 0]
    return {
        "wall_s": wall,
        "completions": comps,
        "healthy": healthy,
        "ttft": ttft, "itl": itl, "e2e": e2e,
        "summary": core.run_summary(),
    }


def _goodput(point: dict, slo_ttft: float, slo_itl: float) -> dict:
    good = [c for c in point["healthy"]
            if c.ttft_s <= slo_ttft
            and c.tokens_per_second > 0
            and 1.0 / c.tokens_per_second <= slo_itl]
    wall = max(point["wall_s"], 1e-9)
    pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
    return {
        "wall_s": point["wall_s"],
        "completed": len(point["completions"]),
        "healthy": len(point["healthy"]),
        "good": len(good),
        "goodput_rps": len(good) / wall,
        "goodput_tok_s": sum(len(c.tokens) for c in good) / wall,
        "p50_ttft_s": pct(point["ttft"], 50),
        "p99_ttft_s": pct(point["ttft"], 99),
        "p50_itl_s": pct(point["itl"], 50),
        "p99_itl_s": pct(point["itl"], 99),
        "run_summary": point["summary"],
    }


def _forced_overload_smoke(eng_factory, *, vocab, prompt_len, max_new,
                           slots, segment_len) -> dict:
    """Deterministic overload exercise (the CI smoke's teeth): drive the
    synchronous core straight into preemption AND shedding, so the ladder
    paths run on every PR regardless of wall-clock timing."""
    eng = eng_factory()
    # compress_at=0.5 drives the degraded-admission rung here too, which
    # doubles as the compile warmup for the measured sweep (the ladder's
    # max_keep program would otherwise compile inside a measured cell)
    adm = AdmissionConfig(shed_at=1.0, reject_at=50.0, compress_at=0.5,
                          enable_shed=True, enable_preempt=True)
    core = FrontDoorCore(eng, batch_slots=slots, segment_len=segment_len,
                         admission=adm)
    rng = np.random.default_rng(3)
    P = lambda: rng.integers(0, vocab, size=prompt_len).astype(np.int32)
    # residents: low priority, long budgets
    core.submit([ServeRequest(uid=i, prompt=P(), max_new_tokens=8 * max_new,
                              priority=0) for i in range(slots)])
    core.step()
    # a high-priority arrival must preempt a resident...
    core.submit([ServeRequest(uid=100, prompt=P(), max_new_tokens=4,
                              priority=5)])
    core.step()
    # ...and a burst of low-priority work must shed under shed_at=1.0
    core.submit([ServeRequest(uid=200 + i, prompt=P(), max_new_tokens=max_new,
                              priority=0) for i in range(4 * slots)])
    core.run()
    s = core.run_summary()
    assert s["preempted"] >= 1, s
    assert s["shed"] >= 1, s
    assert s["completed"] == slots + 1 + 4 * slots, s
    return s


def _warm_group_sizes(eng_factory, *, vocab, prompt_len, slots,
                      segment_len) -> None:
    """Compile the prefill/degrade programs for every admission group size.

    Closed-loop runs only ever admit ``slots``-wide groups (all free slots
    refill at once), but open-loop arrivals trickle in and produce groups
    of every size 1..slots — each a distinct jitted program. Without this
    pass those compiles land inside the first measured cell, stall the
    loop for seconds, and masquerade as queueing."""
    for compress in (float("inf"), 0.0):
        for k in range(1, slots + 1):
            adm = AdmissionConfig(compress_at=compress,
                                  shed_at=float("inf"),
                                  reject_at=float("inf"),
                                  enable_shed=False, enable_preempt=False)
            core = FrontDoorCore(eng_factory(), batch_slots=slots,
                                 segment_len=segment_len, admission=adm)
            rng = np.random.default_rng(7)
            core.submit([ServeRequest(
                uid=i,
                prompt=rng.integers(0, vocab,
                                    size=prompt_len).astype(np.int32),
                max_new_tokens=segment_len) for i in range(k)])
            core.run()


def benchmark(*, tiny: bool = False, out_path: str | None = None,
              csv: common.CsvOut | None = None) -> dict:
    if tiny:
        cfg, capacity = common.bench_arch(512), 32
        slots, segment_len, prompt_len, max_new = 2, 4, 12, 12
        n_calib, load_mults, window_s, n_cap = 8, (0.5, 3.0), 0.25, 64
    else:
        cfg = dataclasses.replace(common.bench_arch(512), n_layers=6,
                                  d_model=256, n_heads=8, n_kv_heads=4,
                                  d_head=32, d_ff=512)
        capacity = 64
        slots, segment_len, prompt_len, max_new = 4, 8, 32, 32
        n_calib, load_mults, window_s, n_cap = 24, (0.5, 1.0, 2.0, 4.0), \
            2.0, 400

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = common.make_policy_for("lethe", capacity)

    # one shared engine: every cell gets a FRESH live state (built by each
    # FrontDoorCore), but the jitted prefill/segment programs compile once
    # for the whole sweep
    eng = Engine(model, params, pol)

    def eng_factory() -> Engine:
        return eng

    # always exercise the overload state machine deterministically (this is
    # what `--tiny` contributes to CI: forced preemption + shedding)
    forced = _forced_overload_smoke(
        eng_factory, vocab=cfg.vocab_size, prompt_len=prompt_len,
        max_new=max_new, slots=slots, segment_len=segment_len)
    print(f"  [slo_serving] forced-overload smoke: "
          f"preempted={forced['preempted']} shed={forced['shed']}",
          flush=True)

    t0 = time.perf_counter()
    _warm_group_sizes(eng_factory, vocab=cfg.vocab_size,
                      prompt_len=prompt_len, slots=slots,
                      segment_len=segment_len)
    print(f"  [slo_serving] warmed admission group sizes 1..{slots} "
          f"({time.perf_counter() - t0:.1f}s)", flush=True)

    # calibrate the service rate closed-loop (everything arrives at t=0,
    # ladder off): μ = healthy requests per second at full occupancy
    calib_reqs = _make_requests(n_calib, prompt_len, max_new,
                                cfg.vocab_size)
    calib = _run_load_point(eng_factory, calib_reqs, [0.0] * n_calib,
                            _naive_admission(), slots=slots,
                            segment_len=segment_len)
    mu = len(calib["healthy"]) / max(calib["wall_s"], 1e-9)
    print(f"  [slo_serving] calibrated service rate μ={mu:.3f} req/s",
          flush=True)

    results = {"config": {
        "device_topology": common.device_topology(),
        "tiny": tiny, "prompt_len": prompt_len,
        "max_new": max_new, "slots": slots, "segment_len": segment_len,
        "capacity": capacity, "policy": "lethe",
        "kv_format": pol.kv_format,
        "n_layers": cfg.n_layers, "d_model": cfg.d_model,
        "service_rate_rps": mu,
        "load_multipliers": list(load_mults),
        "arrival_window_s": window_s,
        "forced_overload_smoke": forced,
    }, "loads": {}}

    slo_ttft = slo_itl = None
    rng = np.random.default_rng(11)
    for mult in load_mults:
        lam = mult * mu
        # FIXED arrival window, request count scales with offered load —
        # a fixed count would let the naive door drain any burst in
        # bounded time and never miss an SLO; sustained overload is the
        # regime the curve exists to show
        n_req = min(max(2 * slots, int(round(lam * window_s))), n_cap)
        reqs = _make_requests(n_req, prompt_len, max_new, cfg.vocab_size,
                              seed=1000 + int(mult * 10))
        gaps = list(rng.exponential(1.0 / lam, size=n_req))
        cell: dict = {"offered_rps": lam, "n_requests": n_req}
        for name, adm in (("naive", _naive_admission()),
                          ("robust", _robust_admission())):
            point = _run_load_point(eng_factory, reqs, gaps, adm,
                                    slots=slots, segment_len=segment_len)
            if slo_ttft is None:
                # SLO thresholds calibrated from the first cell — the
                # naive run at the lowest (sub-saturation) load, i.e. the
                # unloaded system with no ladder churn. The TTFT SLO is
                # sized against END-TO-END request latency (2x its
                # unloaded median): unloaded TTFT is just a prefill
                # (milliseconds), so any multiple of it is dwarfed by any
                # queueing at all — an SLO on that scale fails *every*
                # loaded system. On the e2e scale a door that bounds its
                # backlog (~1.5 pool-fills) keeps its admitted requests
                # inside the SLO, while an unbounded queue blows past it.
                med = lambda xs: float(np.median(xs)) if xs else 1.0
                slo_ttft = 2.0 * max(med(point["e2e"]), 1e-3)
                slo_itl = 3.0 * max(med(point["itl"]), 1e-4)
                results["config"]["slo_ttft_s"] = slo_ttft
                results["config"]["slo_itl_s"] = slo_itl
            cell[name] = _goodput(point, slo_ttft, slo_itl)
        results["loads"][f"{mult:g}x"] = cell
        line = (f"load={mult:g}x ({lam:.2f} rps) "
                f"robust={cell['robust']['goodput_rps']:.3f} grps "
                f"(shed={cell['robust']['run_summary']['shed']} "
                f"preempt={cell['robust']['run_summary']['preempted']}) "
                f"naive={cell['naive']['goodput_rps']:.3f} grps "
                f"(p99 ttft {cell['naive']['p99_ttft_s']:.2f}s)")
        print(f"  [slo_serving] {line}", flush=True)
        if csv is not None:
            csv.add(f"slo_serving/load{mult:g}x",
                    1e6 / max(cell["robust"]["goodput_rps"], 1e-9),
                    f"goodput_rps={cell['robust']['goodput_rps']:.3f};"
                    f"naive={cell['naive']['goodput_rps']:.3f}")

    # graceful degradation: robust goodput past saturation holds near its
    # peak instead of collapsing with offered load
    over = [results["loads"][f"{m:g}x"]["robust"]["goodput_rps"]
            for m in load_mults if m > 1.0]
    peak = max(results["loads"][f"{m:g}x"]["robust"]["goodput_rps"]
               for m in load_mults)
    floor = min(over) if over else peak
    results["graceful_degradation"] = {
        "robust_peak_goodput_rps": peak,
        "robust_min_overload_goodput_rps": floor,
        "retention": floor / max(peak, 1e-9),
        "naive_at_max_load_rps":
            results["loads"][f"{load_mults[-1]:g}x"]["naive"]["goodput_rps"],
        "robust_at_max_load_rps":
            results["loads"][f"{load_mults[-1]:g}x"]["robust"]["goodput_rps"],
    }
    if not tiny:
        # plateau, not collapse: past saturation the robust door keeps at
        # least half its peak goodput at every swept load
        assert results["graceful_degradation"]["retention"] >= 0.5, \
            results["graceful_degradation"]

    out_path = out_path or os.path.join(common.CACHE_DIR,
                                        "BENCH_slo_serving.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"  [slo_serving] wrote {out_path}", flush=True)
    return results


def run(csv: common.CsvOut) -> None:
    """benchmarks/run.py suite hook."""
    benchmark(tiny=False, csv=csv)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: forced preemption/shedding + a 2-point "
                         "load sweep on the tiny bench arch")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = benchmark(tiny=args.tiny, out_path=args.out)
    gd = res["graceful_degradation"]
    print(f"retention past saturation: {gd['retention']:.2f} "
          f"(robust {gd['robust_at_max_load_rps']:.3f} vs naive "
          f"{gd['naive_at_max_load_rps']:.3f} rps at max load)")


if __name__ == "__main__":
    main()
