"""Reasoning-quality gate: every pruning family vs FullKV on held-out
long-generation continuations.

The harness "Hold Onto That Thought" (arXiv 2512.12008) argues for: a FullKV
engine greedy-generates a long CoT continuation from a held-out reasoning
prompt, then every pruning family teacher-force-decodes the *same*
continuation through its pruned cache at a matched cache budget. Three
quality metrics per (family, kv_format) cell:

  * ``agreement``  — fraction of continuation positions where the family's
    greedy argmax matches the FullKV continuation token (per-token
    agreement; FullKV scores 1.0 on its own continuation by greedy
    self-consistency, which doubles as a harness sanity gate);
  * ``kl``         — mean KL(FullKV || family) of the next-token
    distributions over the continuation (logit divergence);
  * ``delta_nll``  — mean extra nats the family pays on the continuation
    tokens vs FullKV (perplexity-style: exp(delta_nll) is the ppl ratio).

Families are matched *within* a kv_format: the int8 grid is scored against
the int8 FullKV reference so quantization error never masquerades as
pruning error. ``cache_bytes`` records the physical per-cell cache cost so
rows are comparable across formats at matched bytes.

Modes:
  * full (default): trained tiny reasoning model (cached under
    experiments/), binding budgets, writes the ``quality`` section of
    experiments/BENCH_policy_quality.json.
  * ``--tiny``: the CI gate. Random-init weights, two sweeps:
      1. non-binding budgets (recent window >= context): every family must
         agree 1.0 with FullKV — the whole-grid differential correctness
         gate (pruning that never fires must be exact, bf16 AND int8);
      2. binding budgets: every cell must produce finite metrics.
    Writes the ``tiny`` section and exits non-zero on gate failure.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import (CACHE_DIR, PRUNING_FAMILIES, REASONING, bench_arch,
                    device_topology, kl_vs_reference, make_policy_for,
                    merge_json_section, teacher_forced_decode, train_model)
from repro.core.policy import make_policy
from repro.data import pipeline
from repro.models.api import build_model
from repro.serving.engine import Engine, _cache_stats

KV_FORMATS = ("bf16", "int8")
OUT = os.path.join(CACHE_DIR, "BENCH_policy_quality.json")


def held_out_prompt(batch_size: int, prompt_len: int, seed: int = 77_000):
    """Held-out CoT prefix (seed far from the training stream): the model
    continues the modular-arithmetic chain from mid-reasoning."""
    dcfg = REASONING
    b = pipeline.reasoning_batch(dcfg, seed)
    toks = np.asarray(b["tokens"])[:batch_size]
    return jnp.asarray(toks[:, :prompt_len])


def family_policy(kind: str, capacity: int, kv_format: str,
                  non_binding: bool):
    if non_binding:
        # recent window covers the whole budget -> nothing is ever evicted
        # (every keep-rule retains the full valid set); budgets stay above
        # any occupancy this run reaches, so prune triggers never fire.
        return make_policy_for(kind, capacity, kv_format=kv_format,
                               recent_ratio=1.0, target_fill=0.75)
    return make_policy_for(kind, capacity, kv_format=kv_format)


def score_grid(model, params, *, prompt_len: int, gen: int, batch: int,
               cap_family: int, cap_full: int, non_binding: bool) -> dict:
    """One (families x kv_formats) sweep -> metric cells."""
    prompt = held_out_prompt(batch, prompt_len)
    grid = {}
    for fmt in KV_FORMATS:
        ref_pol = make_policy(
            "fullkv", capacity=cap_full, sink_len=4, kv_format=fmt)
        eng = Engine(model, params, ref_pol)
        ref = eng.generate({"tokens": prompt}, gen)
        tokens = jnp.concatenate(
            [prompt, jnp.asarray(ref.tokens)], axis=1)      # [B, S+G]

        cells = {}
        for kind in ("fullkv",) + PRUNING_FAMILIES:
            cap = cap_full if kind == "fullkv" else cap_family
            pol = (ref_pol if kind == "fullkv"
                   else family_policy(kind, cap, fmt, non_binding))
            logits = teacher_forced_decode(
                model, params, pol, tokens, prompt_len)      # [G, B, V]
            logp = np.asarray(jax.nn.log_softmax(logits))
            if kind == "fullkv":
                ref_logp = logp
            cont = np.asarray(tokens[:, prompt_len:]).T      # [G, B]
            pred = logp.argmax(-1)
            nll = -np.take_along_axis(
                logp, cont[..., None], axis=-1).mean()
            ref_nll = -np.take_along_axis(
                ref_logp, cont[..., None], axis=-1).mean()
            _, state = model.prefill(
                params, {"tokens": tokens[:, :prompt_len]}, pol)
            cells[kind] = {
                "capacity": cap,
                "cache_bytes": int(_cache_stats(state)["cache_bytes"]),
                "agreement": float((pred == cont).mean()),
                "kl": kl_vs_reference(
                    logp.reshape(-1, logp.shape[-1]),
                    ref_logp.reshape(-1, ref_logp.shape[-1])),
                "delta_nll": float(nll - ref_nll),
            }
            print(f"  [{fmt}] {kind:>12s}: agree={cells[kind]['agreement']:.3f} "
                  f"kl={cells[kind]['kl']:.4f} "
                  f"dnll={cells[kind]['delta_nll']:+.4f} "
                  f"bytes={cells[kind]['cache_bytes']}")
        grid[fmt] = cells
    return grid


def check_gates(grid: dict, *, non_binding: bool) -> list[str]:
    fails = []
    for fmt, cells in grid.items():
        for kind, m in cells.items():
            if not all(np.isfinite([m["agreement"], m["kl"],
                                    m["delta_nll"]])):
                fails.append(f"{fmt}/{kind}: non-finite metrics {m}")
            if not 0.0 <= m["agreement"] <= 1.0:
                fails.append(f"{fmt}/{kind}: agreement out of range {m}")
        if cells["fullkv"]["agreement"] != 1.0:
            fails.append(f"{fmt}/fullkv: greedy self-consistency broken "
                         f"(agreement={cells['fullkv']['agreement']})")
        if non_binding:
            for kind, m in cells.items():
                if m["agreement"] != 1.0 or m["kl"] > 1e-5:
                    fails.append(
                        f"{fmt}/{kind}: non-binding budget must be exact "
                        f"(agreement={m['agreement']}, kl={m['kl']})")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI gate: random-init weights, non-binding "
                         "exactness sweep + binding finiteness sweep")
    ap.add_argument("--gen", type=int, default=None,
                    help="continuation length (default 40 full / 12 tiny)")
    args = ap.parse_args()

    if args.tiny:
        cfg = bench_arch(REASONING.vocab_size)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        gen = args.gen or 12
        prompt_len, batch = 16, 4
        print("== tiny gate 1/2: non-binding budgets (must be exact) ==")
        g_exact = score_grid(model, params, prompt_len=prompt_len, gen=gen,
                             batch=batch, cap_family=128, cap_full=128,
                             non_binding=True)
        print("== tiny gate 2/2: binding budgets (must be finite) ==")
        g_bind = score_grid(model, params, prompt_len=prompt_len, gen=gen,
                            batch=batch, cap_family=24, cap_full=64,
                            non_binding=False)
        fails = (check_gates(g_exact, non_binding=True)
                 + check_gates(g_bind, non_binding=False))
        merge_json_section(OUT, "tiny", {
            "config": {"prompt_len": prompt_len, "gen": gen, "batch": batch,
                       "trained": False, "device": device_topology()},
            "non_binding": g_exact, "binding": g_bind,
            "gate": "pass" if not fails else fails})
        for f in fails:
            print("GATE FAIL:", f)
        print("tiny policy-quality gate:", "PASS" if not fails else "FAIL")
        return 1 if fails else 0

    model, params = train_model("reasoning")
    gen = args.gen or 40
    prompt_len, batch = 20, 8
    print("== policy quality grid (trained model, binding budgets) ==")
    grid = score_grid(model, params, prompt_len=prompt_len, gen=gen,
                      batch=batch, cap_family=32, cap_full=96,
                      non_binding=False)
    fails = check_gates(grid, non_binding=False)
    merge_json_section(OUT, "quality", {
        "config": {"prompt_len": prompt_len, "gen": gen, "batch": batch,
                   "trained": True, "cap_family": 32, "cap_full": 96,
                   "device": device_topology()},
        "grid": grid,
        "gate": "pass" if not fails else fails})
    for f in fails:
        print("GATE FAIL:", f)
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
