"""Prefix reuse under Zipfian prefix popularity: hit rate vs TTFT/throughput.

Production reasoning traffic repeats prefixes — system prompts, few-shot
templates, multi-turn history — with a popularity curve that is Zipfian,
not uniform. This bench measures what the content-hashed prefix store
(``serving/prefix_cache.py``) buys under that law, at both storage formats:

* **Admission latency** — time-to-first-token of a *full-prefix hit*
  (stored rows ``insert_slots``-ed, no prefill) vs a *cold admission*
  (full prefill), and of a *partial hit* (suffix-only resumed prefill)
  vs recomputing the whole prompt. The paper-level claim asserted here:
  a full hit admits at least 3x faster than cold.

* **Traffic curves** — a Zipf-α sweep replayed through the scheduler with
  the store enabled: measured hit rate, wall time, and throughput per α
  (steeper α ⇒ more repetition ⇒ higher hit rate ⇒ more admissions served
  from host RAM instead of the accelerator).

Both sections run at ``kv_format`` bf16 AND int8 — a Lethe store entry
holds *compressed, quantized* KV, so an int8 hit re-admits at half the
bytes (the config block records hit rate and format per cell).

Emits ``experiments/BENCH_prefix_reuse.json``. Standalone:
    PYTHONPATH=src python benchmarks/prefix_reuse.py [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cache as cache_lib
from repro.core.policy import make_policy
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.serving.prefix_cache import (PrefixCache, PrefixCacheConfig,
                                        prefix_fingerprint)
from repro.serving.scheduler import Request, Scheduler


def _zipf_requests(rng, templates, *, n, alpha, p_full, suffix_len, vocab):
    """Zipfian replay: each request picks a template by Zipf(α) rank
    popularity, then either repeats it exactly (full-hit candidate) or
    extends it with a unique suffix (partial-hit candidate)."""
    ranks = np.arange(1, len(templates) + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    reqs = []
    for i in range(n):
        t = templates[rng.choice(len(templates), p=probs)]
        if rng.random() < p_full:
            prompt = t.copy()
        else:
            prompt = np.concatenate(
                [t, rng.integers(1, vocab, size=suffix_len)]
            ).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new_tokens=4))
    return reqs


def _time_admissions(eng, prompt, *, reps):
    """Per-admission TTFT three ways: cold prefill, full-prefix hit, and
    suffix-only resume of a stored prefix. Programs are warmed before the
    timed loop; the hit path times the same work the scheduler does on a
    hit (host->device insert of the snapshot rows)."""
    batch = {"tokens": jnp.asarray(prompt)[None, :]}
    s_prefix = len(prompt) - len(prompt) // 4
    prefix, suffix = prompt[:s_prefix], prompt[s_prefix:]

    # warm every program + capture the snapshot the hit paths replay
    logits, rows = eng.prefill_rows(batch)
    jax.block_until_ready(logits)
    snap = cache_lib.extract_slots(rows, [0])
    _, prows = eng.prefill_rows({"tokens": jnp.asarray(prefix)[None, :]})
    psnap = cache_lib.extract_slots(prows, [0])
    # the insert donates its input state, so the timed loop threads the
    # returned state through a one-element holder
    held = [eng.new_decode_state(2)]

    def _hit():
        held[0] = cache_lib.insert_slots(held[0], [0], snap)
        return held[0].length

    jax.block_until_ready(_hit())
    rl, rr = eng.resume_prefill_rows(
        psnap, {"tokens": jnp.asarray(suffix)[None, :]},
        s_prefix=s_prefix, chunk_size=32)
    jax.block_until_ready(rl)

    def med(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    cold_s = med(lambda: eng.prefill_rows(batch)[0])
    hit_s = med(_hit)
    resume_s = med(lambda: eng.resume_prefill_rows(
        psnap, {"tokens": jnp.asarray(suffix)[None, :]},
        s_prefix=s_prefix, chunk_size=32)[0])
    return {
        "cold_ttft_s": cold_s,
        "full_hit_ttft_s": hit_s,
        "full_hit_speedup": cold_s / max(hit_s, 1e-9),
        "partial_hit_ttft_s": resume_s,
        "partial_hit_speedup": cold_s / max(resume_s, 1e-9),
        "suffix_frac": len(suffix) / len(prompt),
    }


def _zipf_sweep(eng, fp_unused, *, vocab, alphas, n_templates, prefix_len,
                suffix_len, n_req, p_full, slots, seed):
    """Replay each α's trace twice — store on, store off — through the
    scheduler; report measured hit rate and the throughput delta."""
    out = {}
    rng = np.random.default_rng(seed)
    templates = [rng.integers(1, vocab, size=prefix_len).astype(np.int32)
                 for _ in range(n_templates)]
    for alpha in alphas:
        reqs = _zipf_requests(rng, templates, n=n_req, alpha=alpha,
                              p_full=p_full, suffix_len=suffix_len,
                              vocab=vocab)
        cells = {}
        for store_on in (False, True):
            pc = (PrefixCache(PrefixCacheConfig(block_size=32))
                  if store_on else None)
            sched = Scheduler(eng, batch_slots=slots, segment_len=4,
                              prefix_cache=pc)
            sched.submit([Request(uid=r.uid, prompt=r.prompt.copy(),
                                  max_new_tokens=r.max_new_tokens)
                          for r in reqs])
            t0 = time.perf_counter()
            done = sched.run()
            wall = time.perf_counter() - t0
            toks = sum(len(c.tokens) for c in done)
            s = sched.run_summary()
            cells["store" if store_on else "cold"] = {
                "wall_s": wall,
                "throughput_tok_s": toks / max(wall, 1e-9),
                "mean_ttft_s": float(np.mean([c.ttft_s for c in done])),
                "full_hits": s["prefix_full_hits"],
                "partial_hits": s["prefix_partial_hits"],
                "hit_rate": (s["prefix_cache"]["hit_rate"]
                             if store_on else 0.0),
            }
        cells["speedup"] = (cells["cold"]["wall_s"]
                            / max(cells["store"]["wall_s"], 1e-9))
        out[f"{alpha:g}"] = cells
    return out


def benchmark(*, tiny: bool = False, out_path: str | None = None,
              csv: common.CsvOut | None = None) -> dict:
    if tiny:
        capacity, prompt_len, reps = 32, 24, 5
        alphas, n_templates, n_req, slots = (1.5,), 3, 10, 1
        prefix_len, suffix_len, p_full = 16, 8, 0.5
    else:
        capacity, prompt_len, reps = 96, 80, 20
        alphas, n_templates, n_req, slots = (0.8, 1.2, 1.8), 8, 48, 1
        prefix_len, suffix_len, p_full = 32, 16, 0.5

    cfg = common.bench_arch(512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, cfg.vocab_size, size=prompt_len
                          ).astype(np.int32)

    results = {"config": {
        "device_topology": common.device_topology(),
        "tiny": tiny, "capacity": capacity, "policy": "lethe",
        "prompt_len": prompt_len, "prefix_len": prefix_len,
        "suffix_len": suffix_len, "p_full": p_full,
        "zipf_alphas": list(alphas), "n_templates": n_templates,
        "n_requests": n_req, "timing_reps": reps,
        "kv_formats": ["bf16", "int8"],
    }, "formats": {}}

    for kv_format in ("bf16", "int8"):
        pol = make_policy("lethe", capacity=capacity, sink_len=4,
                          sparse_ratio=20.0, recent_ratio=0.3,
                          target_fill=0.6, gamma=0.995,
                          kv_format=kv_format)
        eng = Engine(model, params, pol)
        fp = prefix_fingerprint(pol, eng.cache_dtype, arch=cfg.name)

        adm = _time_admissions(eng, prompt, reps=reps)
        zipf = _zipf_sweep(eng, fp, vocab=cfg.vocab_size, alphas=alphas,
                           n_templates=n_templates, prefix_len=prefix_len,
                           suffix_len=suffix_len, n_req=n_req,
                           p_full=p_full, slots=slots, seed=9)
        hit_rates = {a: zipf[a]["store"]["hit_rate"] for a in zipf}
        results["formats"][kv_format] = {
            "kv_format": kv_format,
            "admission_ttft": adm,
            "zipf": zipf,
            "hit_rate_by_alpha": hit_rates,
        }
        line = (f"{kv_format}: full-hit {adm['full_hit_speedup']:.1f}x, "
                f"partial {adm['partial_hit_speedup']:.1f}x vs cold; "
                f"hit rates " + ", ".join(
                    f"α={a}:{r:.2f}" for a, r in hit_rates.items()))
        print(f"  [prefix_reuse] {line}", flush=True)
        if csv is not None:
            csv.add(f"prefix_reuse/{kv_format}/full_hit",
                    adm["full_hit_ttft_s"] * 1e6,
                    f"speedup={adm['full_hit_speedup']:.1f}x;"
                    f"kv_format={kv_format}")

    if not tiny:
        # the acceptance criterion: a full-prefix hit admits >= 3x faster
        # than a cold prefill, in both storage formats
        for kv_format, fmt in results["formats"].items():
            sp = fmt["admission_ttft"]["full_hit_speedup"]
            assert sp >= 3.0, (kv_format, sp)
        # steeper popularity ⇒ weakly higher measured hit rate (bf16 cell)
        hr = [results["formats"]["bf16"]["zipf"][f"{a:g}"]["store"]
              ["hit_rate"] for a in alphas]
        assert hr[-1] >= hr[0], hr

    out_path = out_path or os.path.join(common.CACHE_DIR,
                                        "BENCH_prefix_reuse.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"  [prefix_reuse] wrote {out_path}", flush=True)
    return results


def run(csv: common.CsvOut) -> None:
    """benchmarks/run.py suite hook."""
    benchmark(tiny=False, csv=csv)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one α, few reps, no speedup assertion")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = benchmark(tiny=args.tiny, out_path=args.out)
    for kv_format, fmt in res["formats"].items():
        adm = fmt["admission_ttft"]
        print(f"{kv_format}: cold {adm['cold_ttft_s'] * 1e3:.2f}ms, "
              f"full hit {adm['full_hit_ttft_s'] * 1e3:.2f}ms "
              f"({adm['full_hit_speedup']:.1f}x), partial "
              f"{adm['partial_hit_ttft_s'] * 1e3:.2f}ms "
              f"({adm['partial_hit_speedup']:.1f}x)")


if __name__ == "__main__":
    main()
