"""Table 2 reproduction: per-device generation memory vs batch size.

Paper: peak GPU MB for batch 1..32, FullKV OOMs at 32. Here: exact KV-cache
bytes (the paper's "generation memory" is cache-dominated; Appendix Fig. 6)
for batch 1..16 plus the projected A100-80GB OOM point for the full-size
DeepSeek-R1-Distill-Qwen-7B geometry at 20k tokens — reproducing the OOM
row analytically from the same arithmetic the paper's Table 2 exhibits."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.serving.engine import Engine


def run(csv: common.CsvOut) -> None:
    model, params = common.train_model("reasoning")
    seq0 = common.REASONING.seq_len
    gen = 48
    for kind in ("fullkv", "lethe"):
        for batch in (1, 4, 8, 16):
            cap = seq0 + gen + 8 if kind == "fullkv" else 32
            pol = common.make_policy_for(kind, cap)
            eng = Engine(model, params, pol)
            toks = np.random.default_rng(0).integers(
                0, model.cfg.vocab_size, size=(batch, seq0)).astype(np.int32)
            t0 = time.time()
            res = eng.generate({"tokens": jnp.asarray(toks)}, gen)
            us = (time.time() - t0) * 1e6 / (batch * gen)
            csv.add(f"table2/{kind}/batch{batch}", us,
                    f"cache_mb={res.cache_bytes/2**20:.2f};"
                    f"tput={res.tokens_per_second:.1f}")

    # analytic OOM projection at paper scale (Qwen-7B geometry, fp16):
    # 28 layers × 4 kv heads × 128 dh × 2 (K,V) × 2 B — per token per seq
    per_tok = 28 * 4 * 128 * 2 * 2
    for batch in (1, 8, 16, 32):
        full_gb = per_tok * 20_000 * batch / 2**30
        lethe_gb = per_tok * 4096 * batch / 2**30
        oom = "OOM" if full_gb > 80 * 0.6 else "fits"
        csv.add(f"table2/projected7b/batch{batch}", 0.0,
                f"fullkv_gb={full_gb:.1f}({oom});lethe_gb={lethe_gb:.1f}(fits)")
