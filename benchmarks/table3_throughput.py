"""Table 3 reproduction: decode throughput (tokens/s) vs batch size across
policies — the paper's headline 2.56× comes from Lethe attending over a
pruned cache while FullKV attends over everything."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.serving.engine import Engine


def run(csv: common.CsvOut) -> None:
    model, params = common.train_model("reasoning")
    # longer synthetic context so attention length dominates decode cost
    seq0, gen = 384, 64
    rng = np.random.default_rng(0)
    base = None
    for kind in ("fullkv", "streaming", "h2o", "pyramidkv", "lethe"):
        for batch in (1, 4, 8):
            cap = seq0 + gen + 8 if kind == "fullkv" else 64
            pol = common.make_policy_for(kind, cap)
            eng = Engine(model, params, pol)
            toks = rng.integers(0, model.cfg.vocab_size,
                                size=(batch, seq0)).astype(np.int32)
            res = eng.generate_scan({"tokens": jnp.asarray(toks)}, gen)
            # second run = steady-state (compile excluded)
            res = eng.generate_scan({"tokens": jnp.asarray(toks)}, gen)
            tput = res.tokens_per_second
            if kind == "fullkv" and batch == 8:
                base = tput
            speedup = (f";speedup_vs_fullkv={tput/base:.2f}"
                       if (base and batch == 8) else "")
            csv.add(f"table3/{kind}/batch{batch}",
                    1e6 / max(tput, 1e-9),
                    f"tokens_per_s={tput:.1f};cache_mb="
                    f"{res.cache_bytes/2**20:.2f}{speedup}")
