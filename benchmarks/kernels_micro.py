"""Kernel microbenchmarks.

Three suites:
  * fused decode-attention+RASR wall time on the XLA-native ref path
    (interpret-mode kernel timing is meaningless on CPU; this validates the
    FLOP accounting used in the roofline);
  * the occupancy sweep behind the early-exit claim (DESIGN.md §2.3):
    the kernel's in-kernel block counter must track live tokens, not the
    static capacity C. Results land in experiments/BENCH_decode_occupancy.json
    so the perf trajectory records the claim over time;
  * ``--quant``: the int8 cache-DMA sweep (DESIGN.md §Quantization) — per
    executed C-block the int8 path moves an int8 tile + one f32 scale row
    instead of a bf16 tile, so cache bytes/step drop to (Dh+4)/(2·Dh) of
    bf16 at every occupancy while the early-exit block counts stay equal.
    Results land in the kernel section of experiments/BENCH_kv_quant.json.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cache as cache_lib
from repro.kernels import ops, ref
from repro.kernels.decode_attention import (GLOBAL_WINDOW,
                                            decode_attention_pallas,
                                            live_lengths)


def _decode_ref_us(B, Hq, Hkv, C, Dh, n=20) -> float:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)

    f = jax.jit(lambda q, k, v, pos: ops.decode_attention(
        q, k, v, pos, C, impl="ref"))
    out = f(q, k, v, pos)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(q, k, v, pos)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / n


def _occupancy_sweep(csv: common.CsvOut) -> None:
    """Occupancy ∈ {1/8, 1/4, 1/2, 1}·C: measure the early-exit kernel's
    executed C-block count (in-kernel counter) + interpret-vs-ref max abs
    diff, and the ref-path wall time at the equivalent live length."""
    B, Hq, Hkv, C, Dh, bc = 4, 8, 2, 1024, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    full_blocks = C // bc
    gamma = 0.95

    sweep = []
    for num, den in ((1, 8), (1, 4), (1, 2), (1, 1)):
        live = max(1, (C * num) // den)
        pos = jnp.where(jnp.arange(C)[None, :] < live,
                        jnp.arange(C)[None, :], -1
                        ).astype(jnp.int32).repeat(B, axis=0)
        score = jnp.where(pos >= 0, jax.random.uniform(ks[3], (B, C)), 0.0)
        lens = live_lengths(pos)
        cur = lens - 1

        o_pl, ps_pl, ns_pl, blocks = decode_attention_pallas(
            q, k, v, pos, score, lens, cur, jnp.int32(GLOBAL_WINDOW),
            scale=Dh ** -0.5, gamma=gamma, block_c=bc, interpret=True)
        o_r, ps_r, ns_r = ref.decode_attention_fused_ref(
            q, k, v, pos, cur, score, gamma=gamma, scale=Dh ** -0.5)
        max_out = float(np.abs(np.asarray(o_pl) - np.asarray(o_r)).max())
        max_ps = float(np.abs(np.asarray(ps_pl) - np.asarray(ps_r)).max())
        blocks_bh = int(np.asarray(blocks)[0, 0])

        # XLA-native wall time over the live prefix only — the cost the
        # early-exit kernel achieves on TPU by skipping dead blocks.
        ref_us = _decode_ref_us(B, Hq, Hkv, live, Dh)

        sweep.append({
            "occupancy": num / den,
            "live_tokens": live,
            "blocks_executed": blocks_bh,
            "blocks_full_capacity": full_blocks,
            "max_abs_diff_out": max_out,
            "max_abs_diff_probsum": max_ps,
            "ref_us_at_live_len": ref_us,
        })
        csv.add(f"kernel/decode_occupancy/C{C}live{live}", ref_us,
                f"blocks={blocks_bh}/{full_blocks};"
                f"maxdiff={max(max_out, max_ps):.2e}")

    # Acceptance (ISSUE 1): 1/4 occupancy must cost ≤ 1/2 the full-capacity
    # block iterations, and every swept occupancy matches the oracle ≤ 1e-5.
    quarter = next(s for s in sweep if s["occupancy"] == 0.25)
    full = next(s for s in sweep if s["occupancy"] == 1.0)
    assert quarter["blocks_executed"] * 2 <= full["blocks_executed"], sweep
    assert all(max(s["max_abs_diff_out"], s["max_abs_diff_probsum"]) <= 1e-5
               for s in sweep), sweep

    out_path = os.path.join(common.CACHE_DIR, "BENCH_decode_occupancy.json")
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"shape": {"B": B, "Hq": Hq, "Hkv": Hkv, "C": C, "Dh": Dh,
                             "block_c": bc, "kv_format": "bf16",
                             "kv_payload_itemsize": 2},
                   "device_topology": common.device_topology(),
                   "sweep": sweep}, f, indent=2)
    print(f"# wrote {out_path}")


def _cache_bytes_per_step(blocks: int, block_c: int, Dh: int, *,
                          kv_format: str) -> int:
    """Cache-side HBM bytes one (b, h) decode program DMAs: per executed
    C-block, K + V payload tiles (+ the two f32 scale rows on int8)."""
    if kv_format == "int8":
        return blocks * block_c * (Dh * 1 + 4) * 2
    return blocks * block_c * Dh * 2 * 2            # bf16 payload


def _quant_sweep(csv: common.CsvOut) -> dict:
    """int8-vs-bf16 cache DMA at equal capacity across the occupancy grid:
    the early-exit block counts must be identical (quantization touches
    bytes/block, not which blocks run) and the int8 path must match the
    dequant oracle ≤ 1e-5; bytes/step derive from the measured counts."""
    B, Hq, Hkv, C, Dh, bc = 4, 8, 2, 1024, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    kd = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    vd = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    kq, ksc = cache_lib.quantize_kv(kd)
    vq, vsc = cache_lib.quantize_kv(vd)
    gamma = 0.95

    sweep = []
    for num, den in ((1, 8), (1, 4), (1, 2), (1, 1)):
        live = max(1, (C * num) // den)
        pos = jnp.where(jnp.arange(C)[None, :] < live,
                        jnp.arange(C)[None, :], -1
                        ).astype(jnp.int32).repeat(B, axis=0)
        score = jnp.where(pos >= 0, jax.random.uniform(ks[3], (B, C)), 0.0)
        lens = live_lengths(pos)
        cur = lens - 1

        o_q, ps_q, ns_q, blocks_q = decode_attention_pallas(
            q, kq, vq, pos, score, lens, cur, jnp.int32(GLOBAL_WINDOW),
            scale=Dh ** -0.5, gamma=gamma, block_c=bc, interpret=True,
            k_scale=ksc, v_scale=vsc)
        *_, blocks_d = decode_attention_pallas(
            q, kd, vd, pos, score, lens, cur, jnp.int32(GLOBAL_WINDOW),
            scale=Dh ** -0.5, gamma=gamma, block_c=bc, interpret=True)
        o_r, ps_r, ns_r = ref.decode_attention_fused_ref(
            q, kq, vq, pos, cur, score, gamma=gamma, scale=Dh ** -0.5,
            k_scale=ksc, v_scale=vsc)
        maxdiff = max(
            float(np.abs(np.asarray(o_q) - np.asarray(o_r)).max()),
            float(np.abs(np.asarray(ps_q) - np.asarray(ps_r)).max()),
            float(np.abs(np.asarray(ns_q) - np.asarray(ns_r)).max()))
        nb_q = int(np.asarray(blocks_q)[0, 0])
        nb_d = int(np.asarray(blocks_d)[0, 0])
        bytes_q = _cache_bytes_per_step(nb_q, bc, Dh, kv_format="int8")
        bytes_d = _cache_bytes_per_step(nb_d, bc, Dh, kv_format="bf16")
        sweep.append({
            "occupancy": num / den, "live_tokens": live,
            "blocks_executed_int8": nb_q, "blocks_executed_bf16": nb_d,
            "cache_bytes_per_step_int8": bytes_q,
            "cache_bytes_per_step_bf16": bytes_d,
            "bytes_ratio_int8_over_bf16": bytes_q / bytes_d,
            "max_abs_diff_vs_oracle": maxdiff,
        })
        csv.add(f"kernel/kv_quant/C{C}live{live}", float(bytes_q),
                f"bf16_bytes={bytes_d};ratio={bytes_q/bytes_d:.3f};"
                f"maxdiff={maxdiff:.2e}")

    # Acceptance (ISSUE 5): ≤ ~55% of bf16 cache bytes/step at equal
    # capacity, identical early-exit block counts, oracle-exact ≤ 1e-5.
    assert all(s["bytes_ratio_int8_over_bf16"] <= 0.55 for s in sweep), sweep
    assert all(s["blocks_executed_int8"] == s["blocks_executed_bf16"]
               for s in sweep), sweep
    assert all(s["max_abs_diff_vs_oracle"] <= 1e-5 for s in sweep), sweep

    kernel_section = {
        "shape": {"B": B, "Hq": Hq, "Hkv": Hkv, "C": C, "Dh": Dh,
                  "block_c": bc},
        "device_topology": common.device_topology(),
        "bytes_model": "per (b,h) program: blocks * block_c * "
                       "(payload_itemsize*Dh + scale_bytes) * 2 [K and V]; "
                       "bf16: 2*Dh, int8: 1*Dh + 4 (f32 scale/token/head)",
        "sweep": sweep,
    }
    out_path = os.path.join(common.CACHE_DIR, "BENCH_kv_quant.json")
    common.merge_json_section(out_path, "kernel", kernel_section)
    print(f"# wrote {out_path} (kernel section)")
    return kernel_section


def run(csv: common.CsvOut) -> None:
    for (B, Hq, Hkv, C, Dh) in [(4, 8, 2, 1024, 64), (8, 16, 4, 4096, 128)]:
        us = _decode_ref_us(B, Hq, Hkv, C, Dh)
        flops = 4 * B * Hq * C * Dh  # qk + pv
        csv.add(f"kernel/decode_attn/B{B}H{Hq}C{C}", us,
                f"gflops_s={flops/us/1e3:.2f};probsum_fused=true")
    _occupancy_sweep(csv)
    _quant_sweep(csv)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", action="store_true",
                    help="run only the int8 cache-DMA sweep "
                         "(kernel section of BENCH_kv_quant.json)")
    args = ap.parse_args()
    csv = common.CsvOut()
    if args.quant:
        _quant_sweep(csv)
    else:
        run(csv)


if __name__ == "__main__":
    main()
