"""Kernel microbenchmarks: fused decode-attention+RASR (ref vs interpret
oracle check timing is meaningless on CPU — this reports the XLA-native ref
path wall time and validates the fused kernel's FLOP accounting used in the
roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops


def run(csv: common.CsvOut) -> None:
    for (B, Hq, Hkv, C, Dh) in [(4, 8, 2, 1024, 64), (8, 16, 4, 4096, 128)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, Dh))
        k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
        v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
        pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)

        f = jax.jit(lambda q, k, v, pos: ops.decode_attention(
            q, k, v, pos, C, impl="ref"))
        out = f(q, k, v, pos)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        n = 20
        for _ in range(n):
            out = f(q, k, v, pos)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) * 1e6 / n
        flops = 4 * B * Hq * C * Dh  # qk + pv
        csv.add(f"kernel/decode_attn/B{B}H{Hq}C{C}", us,
                f"gflops_s={flops/us/1e3:.2f};probsum_fused=true")
