"""Kernel microbenchmarks.

Two suites:
  * fused decode-attention+RASR wall time on the XLA-native ref path
    (interpret-mode kernel timing is meaningless on CPU; this validates the
    FLOP accounting used in the roofline);
  * the occupancy sweep behind the early-exit claim (DESIGN.md §2.3):
    the kernel's in-kernel block counter must track live tokens, not the
    static capacity C. Results land in experiments/BENCH_decode_occupancy.json
    so the perf trajectory records the claim over time.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref
from repro.kernels.decode_attention import (GLOBAL_WINDOW,
                                            decode_attention_pallas,
                                            live_lengths)


def _decode_ref_us(B, Hq, Hkv, C, Dh, n=20) -> float:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)

    f = jax.jit(lambda q, k, v, pos: ops.decode_attention(
        q, k, v, pos, C, impl="ref"))
    out = f(q, k, v, pos)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(q, k, v, pos)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / n


def _occupancy_sweep(csv: common.CsvOut) -> None:
    """Occupancy ∈ {1/8, 1/4, 1/2, 1}·C: measure the early-exit kernel's
    executed C-block count (in-kernel counter) + interpret-vs-ref max abs
    diff, and the ref-path wall time at the equivalent live length."""
    B, Hq, Hkv, C, Dh, bc = 4, 8, 2, 1024, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, C, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, C, Dh))
    full_blocks = C // bc
    gamma = 0.95

    sweep = []
    for num, den in ((1, 8), (1, 4), (1, 2), (1, 1)):
        live = max(1, (C * num) // den)
        pos = jnp.where(jnp.arange(C)[None, :] < live,
                        jnp.arange(C)[None, :], -1
                        ).astype(jnp.int32).repeat(B, axis=0)
        score = jnp.where(pos >= 0, jax.random.uniform(ks[3], (B, C)), 0.0)
        lens = live_lengths(pos)
        cur = lens - 1

        o_pl, ps_pl, ns_pl, blocks = decode_attention_pallas(
            q, k, v, pos, score, lens, cur, jnp.int32(GLOBAL_WINDOW),
            scale=Dh ** -0.5, gamma=gamma, block_c=bc, interpret=True)
        o_r, ps_r, ns_r = ref.decode_attention_fused_ref(
            q, k, v, pos, cur, score, gamma=gamma, scale=Dh ** -0.5)
        max_out = float(np.abs(np.asarray(o_pl) - np.asarray(o_r)).max())
        max_ps = float(np.abs(np.asarray(ps_pl) - np.asarray(ps_r)).max())
        blocks_bh = int(np.asarray(blocks)[0, 0])

        # XLA-native wall time over the live prefix only — the cost the
        # early-exit kernel achieves on TPU by skipping dead blocks.
        ref_us = _decode_ref_us(B, Hq, Hkv, live, Dh)

        sweep.append({
            "occupancy": num / den,
            "live_tokens": live,
            "blocks_executed": blocks_bh,
            "blocks_full_capacity": full_blocks,
            "max_abs_diff_out": max_out,
            "max_abs_diff_probsum": max_ps,
            "ref_us_at_live_len": ref_us,
        })
        csv.add(f"kernel/decode_occupancy/C{C}live{live}", ref_us,
                f"blocks={blocks_bh}/{full_blocks};"
                f"maxdiff={max(max_out, max_ps):.2e}")

    # Acceptance (ISSUE 1): 1/4 occupancy must cost ≤ 1/2 the full-capacity
    # block iterations, and every swept occupancy matches the oracle ≤ 1e-5.
    quarter = next(s for s in sweep if s["occupancy"] == 0.25)
    full = next(s for s in sweep if s["occupancy"] == 1.0)
    assert quarter["blocks_executed"] * 2 <= full["blocks_executed"], sweep
    assert all(max(s["max_abs_diff_out"], s["max_abs_diff_probsum"]) <= 1e-5
               for s in sweep), sweep

    out_path = os.path.join(common.CACHE_DIR, "BENCH_decode_occupancy.json")
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"shape": {"B": B, "Hq": Hq, "Hkv": Hkv, "C": C, "Dh": Dh,
                             "block_c": bc},
                   "sweep": sweep}, f, indent=2)
    print(f"# wrote {out_path}")


def run(csv: common.CsvOut) -> None:
    for (B, Hq, Hkv, C, Dh) in [(4, 8, 2, 1024, 64), (8, 16, 4, 4096, 128)]:
        us = _decode_ref_us(B, Hq, Hkv, C, Dh)
        flops = 4 * B * Hq * C * Dh  # qk + pv
        csv.add(f"kernel/decode_attn/B{B}H{Hq}C{C}", us,
                f"gflops_s={flops/us/1e3:.2f};probsum_fused=true")
    _occupancy_sweep(csv)
