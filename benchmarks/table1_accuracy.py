"""Table 1 reproduction: accuracy preservation under cache compression.

Paper: Math500 + MMLU subjects across {FullKV, H2O, StreamingLLM, PyramidKV,
Lethe}. Here: the synthetic chained-arithmetic task (Math500 analogue) and
the long-range recall task (long-context MMLU analogue), tiny in-framework
models, same policy grid, cache budget ≈ 40% of sequence length."""
from __future__ import annotations

import time

from benchmarks import common


def run(csv: common.CsvOut) -> None:
    for task in ("reasoning", "recall"):
        model, params = common.train_model(task)
        seq = (common.REASONING.seq_len if task == "reasoning"
               else common.RECALL.seq_len)
        cap_full = seq + 8
        cap = max(16, int(seq * 0.4))
        ref_logits = None
        for kind in common.POLICY_GRID:
            pol = common.make_policy_for(kind, cap_full if kind == "fullkv"
                                         else cap)
            t0 = time.time()
            r = common.eval_answer_accuracy(model, params, pol, task)
            us = (time.time() - t0) * 1e6 / r["n"]
            if kind == "fullkv":
                ref_logits = r["logits"]
                kl = 0.0
            else:
                kl = common.kl_vs_reference(r["logits"], ref_logits)
            csv.add(f"table1/{task}/{kind}", us,
                    f"acc={r['accuracy']:.3f};kl_vs_fullkv={kl:.4f};"
                    f"capacity={pol.capacity};seq={seq}")
