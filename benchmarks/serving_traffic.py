"""Mixed-length serving traffic: continuous batching vs lockstep.

The paper's throughput tables (2–3) are multi-batch numbers; under real
traffic request lengths are wildly mixed (a short lookup shares slots with a
long chain-of-thought), and a run-to-completion scheduler makes every short
request wait for the batch's longest while finished rows burn kernel work on
dead slots. This benchmark drives the same mixed workload through both
scheduler modes over the Table 2–3 batch-size grid and reports the wall-
clock throughput gap, emitting ``experiments/BENCH_serving_traffic.json``.

Standalone:
    PYTHONPATH=src python benchmarks/serving_traffic.py [--tiny]
or as a suite inside ``benchmarks/run.py`` (suite name ``serving``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _mesh_bootstrap() -> None:
    """``--mesh`` on a CPU host needs fake devices, and the
    ``xla_force_host_platform_device_count`` flag only binds BEFORE the
    first jax import — 8 covers every swept shape (up to 2x4)."""
    if "--mesh" not in sys.argv:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count=8").strip()


_mesh_bootstrap()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models.api import build_model
from repro.serving.engine import Engine, _cache_stats
from repro.serving.scheduler import Request, Scheduler


def _make_requests(n: int, prompt_len: int, max_new_grid: tuple[int, ...],
                   vocab: int, seed: int = 0,
                   long_every: int = 4) -> list[Request]:
    """Mixed workload: mostly short requests with a long reasoning request
    every ``long_every``-th submission — the traffic shape that motivates
    decode-time eviction (a minority of CoT stragglers would otherwise hold
    every lockstep batch hostage). One prompt length (one prefill compile).
    """
    rng = np.random.default_rng(seed)
    short, long = min(max_new_grid), max(max_new_grid)
    return [Request(uid=i,
                    prompt=rng.integers(0, vocab, size=prompt_len,
                                        ).astype(np.int32),
                    max_new_tokens=long if i % long_every == long_every - 1
                    else short)
            for i in range(n)]


def _run_once(mode: str, eng: Engine, reqs: list[Request], slots: int,
              segment_len: int):
    sched = Scheduler(eng, batch_slots=slots, segment_len=segment_len)
    sched.submit(reqs)
    t0 = time.perf_counter()
    done = sched.run() if mode == "continuous" else sched.run_lockstep()
    wall = time.perf_counter() - t0
    assert sorted(c.uid for c in done) == list(range(len(reqs)))
    return wall, done, sched


def _measure(eng: Engine, reqs: list[Request], slots: int, segment_len: int,
             repeats: int) -> dict:
    """Interleave lockstep/continuous runs and keep each mode's best wall
    time: single runs are ±30% noisy on a contended CPU box, and
    interleaving keeps a load burst from penalising one mode only."""
    walls = {"lockstep": [], "continuous": []}
    dones = {}
    summaries = {}
    for _ in range(repeats):
        for mode in ("lockstep", "continuous"):
            wall, done, sched = _run_once(mode, eng, reqs, slots,
                                          segment_len)
            walls[mode].append(wall)
            dones[mode] = done
            summaries[mode] = sched.run_summary()
    out = {}
    for mode, done in dones.items():
        wall = min(walls[mode])
        tokens = int(sum(len(c.tokens) for c in done))
        out[mode] = {
            "wall_s": wall,
            "tokens": tokens,
            "tokens_per_s": tokens / max(wall, 1e-9),
            "mean_ttft_s": float(np.mean([c.ttft_s for c in done])),
            "mean_queue_wait_s": float(np.mean(
                [c.queue_wait_s for c in done])),
            # robustness counters (ISSUE 6): for the plain scheduler the
            # shed/preempt/timeout/failed counts are structurally zero —
            # recording them is what makes an overload run (front door
            # under pressure) distinguishable from this healthy baseline
            "run_summary": summaries[mode],
        }
    return out


def benchmark(*, tiny: bool = False, out_path: str | None = None,
              csv: common.CsvOut | None = None) -> dict:
    if tiny:
        slots_grid, n_req, prompt_len = (4,), 6, 12
        max_new_grid, segment_len = (4, 16), 4
        cfg, capacity = common.bench_arch(512), 48
    else:
        # the acceptance workload: B=8 slots, max_new ∈ {8, 64}, plus the
        # Table 2–3 batch-size sweep around it; enough requests that the
        # drain-out tail (last long request at low occupancy) amortises.
        # The model is larger than the tiny bench arch: at trivial per-step
        # cost the scheduler's host-side boundary tax is the same order as
        # the step savings and the measurement is pure timer noise — at
        # this compute intensity the step savings dominate, stably.
        slots_grid, n_req, prompt_len = (2, 4, 8), 32, 32
        max_new_grid, segment_len = (8, 64), 8
        cfg = dataclasses.replace(common.bench_arch(512), n_layers=6,
                                  d_model=256, n_heads=8, n_kv_heads=4,
                                  d_head=32, d_ff=512)
        capacity = 64

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = common.make_policy_for("lethe", capacity)
    eng = Engine(model, params, pol)
    reqs = _make_requests(n_req, prompt_len, max_new_grid, cfg.vocab_size)

    # benchmark hygiene: record the cache storage format and the physical
    # bytes of one decode state per swept slot count, so runs before/after
    # the quantization PR stay comparable on real memory, not capacity
    results = {"config": {
        "slots_grid": list(slots_grid), "n_requests": n_req,
        "prompt_len": prompt_len, "max_new_grid": list(max_new_grid),
        "segment_len": segment_len, "policy": "lethe", "tiny": tiny,
        "n_layers": cfg.n_layers, "d_model": cfg.d_model,
        "capacity": capacity,
        "kv_format": pol.kv_format,
        "device_topology": common.device_topology(),
        "cache_bytes_per_slots": {
            str(s): _cache_stats(eng.new_decode_state(s))["cache_bytes"]
            for s in slots_grid},
    }, "runs": {}}

    repeats = 1 if tiny else 3
    for slots in slots_grid:
        # warmup pass per mode (compile excluded from the measured runs)
        for mode in ("lockstep", "continuous"):
            _run_once(mode, eng, list(reqs), slots, segment_len)
        measured = _measure(eng, list(reqs), slots, segment_len, repeats)
        lock, cont = measured["lockstep"], measured["continuous"]
        speedup = cont["tokens_per_s"] / max(lock["tokens_per_s"], 1e-9)
        results["runs"][f"slots{slots}"] = {
            "lockstep": lock, "continuous": cont, "speedup": speedup}
        results["config"]["run_summary"] = cont["run_summary"]
        line = (f"slots={slots} lockstep={lock['tokens_per_s']:.1f} tok/s "
                f"continuous={cont['tokens_per_s']:.1f} tok/s "
                f"speedup={speedup:.2f}x")
        print(f"  [serving_traffic] {line}", flush=True)
        if csv is not None:
            csv.add(f"serving_traffic/slots{slots}",
                    1e6 / max(cont["tokens_per_s"], 1e-9),
                    f"tokens_per_s={cont['tokens_per_s']:.1f};"
                    f"speedup_vs_lockstep={speedup:.2f}")

    out_path = out_path or os.path.join(common.CACHE_DIR,
                                        "BENCH_serving_traffic.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"  [serving_traffic] wrote {out_path}", flush=True)
    return results


# --------------------------------------------------------------------------
# Chunked-prefill scenario: long-prompt admission waves vs live decodes.
#
# Residents decode long outputs while long-prompt requests arrive and must
# be admitted mid-stream. Whole-prompt admission stalls every live row for
# the full prompt prefill at one segment boundary; chunked admission spreads
# it one chunk per segment. The measured quantity is the p95 per-token
# segment gap of live decodes (`Scheduler.segment_gap_trace`) — the
# inter-token latency a user sees across an admission wave. Emits
# ``experiments/BENCH_chunked_prefill.json``.
# --------------------------------------------------------------------------

def _chunked_workload(cfg, *, n_resident: int, resident_new: int,
                      long_len: int, long_new: int, n_long: int,
                      seed: int = 0) -> list[Request]:
    """Residents with *staggered* decode budgets (slots free one at a time,
    so every long-prompt admission overlaps live decodes — the stall the
    chunked interleave removes) + long-prompt arrivals."""
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=16).astype(np.int32),
                    max_new_tokens=resident_new * (i + 1))
            for i in range(n_resident)]
    reqs += [Request(uid=100 + j,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=long_len).astype(np.int32),
                     max_new_tokens=long_new)
             for j in range(n_long)]
    return reqs


def _run_chunked_once(eng, reqs, *, slots, segment_len, chunk):
    sched = Scheduler(eng, batch_slots=slots, segment_len=segment_len,
                      prefill_chunk_size=chunk)
    # admission groups here are mostly a single long prompt: padding them
    # to the slot width would burn chunk FLOPs on dummy rows
    sched.pad_admission_rows = False
    sched.submit(reqs)
    t0 = time.perf_counter()
    done = sched.run()
    wall = time.perf_counter() - t0
    assert sorted(c.uid for c in done) == sorted(r.uid for r in reqs)
    gaps = [g / segment_len for live, g in sched.segment_gap_trace
            if live > 0]
    return {
        "wall_s": wall,
        "tokens": int(sum(len(c.tokens) for c in done)),
        "itl_p95_s": float(np.percentile(gaps, 95)) if gaps else 0.0,
        "itl_mean_s": float(np.mean(gaps)) if gaps else 0.0,
        "segments": len(gaps),
        "run_summary": sched.run_summary(),
    }


def benchmark_chunked(*, tiny: bool = False, out_path: str | None = None,
                      csv: common.CsvOut | None = None) -> dict:
    if tiny:
        cfg, capacity = common.bench_arch(512), 96
        slots, segment_len, chunk = 2, 4, 16
        n_resident, resident_new, long_len, long_new, n_long = 2, 8, 64, 8, 2
        repeats = 1
    else:
        # long_len is chosen so one whole-prompt prefill (O(S^2) attention
        # + S rows of FFN, ~2x a decode segment at this scale) far
        # outweighs a single chunk — the regime the stall bound exists for.
        cfg = dataclasses.replace(common.bench_arch(512), n_layers=6,
                                  d_model=256, n_heads=8, n_kv_heads=4,
                                  d_head=32, d_ff=512)
        capacity = 1056
        slots, segment_len, chunk = 4, 8, 64
        n_resident, resident_new, long_len, long_new, n_long = \
            4, 16, 1024, 16, 3
        repeats = 3

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = common.make_policy_for("lethe", capacity)
    eng = Engine(model, params, pol)
    reqs = _chunked_workload(cfg, n_resident=n_resident,
                             resident_new=resident_new, long_len=long_len,
                             long_new=long_new, n_long=n_long)

    results = {"config": {
        "slots": slots, "segment_len": segment_len, "chunk": chunk,
        "capacity": capacity, "n_resident": n_resident,
        "resident_new": resident_new, "long_len": long_len,
        "long_new": long_new, "n_long": n_long, "tiny": tiny,
        "n_layers": cfg.n_layers, "d_model": cfg.d_model,
        "kv_format": pol.kv_format,
        "device_topology": common.device_topology(),
        "cache_bytes": _cache_stats(
            eng.new_decode_state(slots))["cache_bytes"],
    }, "modes": {}}

    # warm both modes (compiles excluded), then interleave measured runs
    for mode_chunk in (None, chunk):
        _run_chunked_once(eng, list(reqs), slots=slots,
                          segment_len=segment_len, chunk=mode_chunk)
    best: dict = {}
    for _ in range(repeats):
        for name, mode_chunk in (("whole_prompt", None), ("chunked", chunk)):
            r = _run_chunked_once(eng, list(reqs), slots=slots,
                                  segment_len=segment_len, chunk=mode_chunk)
            if name not in best or r["itl_p95_s"] < best[name]["itl_p95_s"]:
                best[name] = r
    results["modes"] = best
    ratio = (best["whole_prompt"]["itl_p95_s"]
             / max(best["chunked"]["itl_p95_s"], 1e-12))
    results["p95_itl_whole_over_chunked"] = ratio

    # chunked-only capability: prompts up to 2x capacity admit compressed
    rng = np.random.default_rng(7)
    over = [Request(uid=900 + j,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=2 * capacity).astype(np.int32),
                    max_new_tokens=4) for j in range(2)]
    sched = Scheduler(eng, batch_slots=slots, segment_len=segment_len,
                      prefill_chunk_size=chunk, track_occupancy=True)
    sched.submit(over)
    done = sched.run()
    results["compressed_admission"] = {
        "prompt_len": 2 * capacity, "completed": len(done),
        "max_slot_tokens": int(sched.max_slot_tokens),
        "capacity": capacity,
    }
    results["config"]["run_summary"] = sched.run_summary()
    assert sched.max_slot_tokens <= capacity

    line = (f"p95 ITL whole={best['whole_prompt']['itl_p95_s'] * 1e3:.2f}ms "
            f"chunked={best['chunked']['itl_p95_s'] * 1e3:.2f}ms "
            f"({ratio:.2f}x); 2x-capacity admission ok")
    print(f"  [chunked_prefill] {line}", flush=True)
    if csv is not None:
        csv.add("chunked_prefill/itl_p95",
                best["chunked"]["itl_p95_s"] * 1e6,
                f"whole_over_chunked={ratio:.2f}")

    out_path = out_path or os.path.join(common.CACHE_DIR,
                                        "BENCH_chunked_prefill.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"  [chunked_prefill] wrote {out_path}", flush=True)
    return results


# --------------------------------------------------------------------------
# Quantized-cache scenario (`--kv-format int8`): bytes-neutral throughput.
#
# At a fixed cache-byte budget, int8 block-scaled K/V (≈ 53% of bf16 bytes
# per slot at Dh = 64) funds ~2x the decode slots. Under queued mixed
# traffic more slots drain the queue with more concurrent requests, and the
# per-step cost is sublinear in the live batch (on TPU decode is
# HBM-bandwidth-bound; on this CPU harness the analogous fixed per-step
# dispatch cost dominates at this model scale), so tokens/s rises at equal
# memory. The bf16 baseline runs at B slots with a bf16 cache; int8 runs at
# 2B slots; both physical byte counts are recorded from the live state.
# Emits the serving section of ``experiments/BENCH_kv_quant.json``.
# --------------------------------------------------------------------------

def _run_quant_once(eng: Engine, reqs: list[Request], slots: int,
                    segment_len: int) -> float:
    sched = Scheduler(eng, batch_slots=slots, segment_len=segment_len)
    sched.submit(reqs)
    t0 = time.perf_counter()
    done = sched.run()
    wall = time.perf_counter() - t0
    assert sorted(c.uid for c in done) == sorted(r.uid for r in reqs)
    return sum(len(c.tokens) for c in done) / max(wall, 1e-9)


def benchmark_kv_quant(*, tiny: bool = False, out_path: str | None = None,
                       csv: common.CsvOut | None = None) -> dict:
    if tiny:
        cfg = common.bench_arch(512)
        capacity, slots_bf16, n_req, prompt_len = 32, 2, 6, 12
        max_new_grid, segment_len, repeats = (4, 16), 4, 1
    else:
        # Dh = 64 so the per-slot byte ratio matches the kernel sweep
        # ((64 + 4) / 128 = 53%); model small enough that per-step cost is
        # dispatch/bandwidth-shaped rather than FLOP-bound — the regime
        # where extra slots at equal bytes buy real throughput.
        cfg = dataclasses.replace(common.bench_arch(512), n_layers=4,
                                  d_model=256, n_heads=4, n_kv_heads=2,
                                  d_head=64, d_ff=512)
        capacity, slots_bf16, n_req, prompt_len = 64, 4, 32, 32
        max_new_grid, segment_len, repeats = (8, 64), 8, 3
    slots_int8 = 2 * slots_bf16

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _make_requests(n_req, prompt_len, max_new_grid, cfg.vocab_size)

    def make_engine(fmt: str) -> Engine:
        pol = dataclasses.replace(common.make_policy_for("lethe", capacity),
                                  kv_format=fmt)
        # the dense baseline stores bf16 (the serving dtype the int8 format
        # competes with); int8 ignores cache_dtype for the payload
        return Engine(model, params, pol,
                      cache_dtype=jnp.bfloat16 if fmt == "bf16"
                      else jnp.float32)

    runs = {"bf16": (make_engine("bf16"), slots_bf16),
            "int8": (make_engine("int8"), slots_int8)}
    out = {}
    for name, (eng, slots) in runs.items():     # warmup (compile excluded)
        _run_quant_once(eng, list(reqs), slots, segment_len)
    best: dict[str, float] = {}
    for _ in range(repeats):                    # interleaved best-of
        for name, (eng, slots) in runs.items():
            tps = _run_quant_once(eng, list(reqs), slots, segment_len)
            best[name] = max(best.get(name, 0.0), tps)
    for name, (eng, slots) in runs.items():
        stats = _cache_stats(eng.new_decode_state(slots))
        out[name] = {
            "slots": slots,
            "tokens_per_s": best[name],
            "cache_bytes": stats["cache_bytes"],
            "cache_bytes_breakdown": stats["cache_bytes_breakdown"],
            "kv_format": stats["kv_format"],
        }
    speedup = out["int8"]["tokens_per_s"] / max(out["bf16"]["tokens_per_s"],
                                                1e-9)
    byte_ratio = out["int8"]["cache_bytes"] / out["bf16"]["cache_bytes"]
    serving_section = {
        "config": {
            "n_requests": n_req, "prompt_len": prompt_len,
            "max_new_grid": list(max_new_grid), "segment_len": segment_len,
            "capacity": capacity, "policy": "lethe", "tiny": tiny,
            "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "d_head": cfg.d_head,
            "device_topology": common.device_topology(),
        },
        "runs": out,
        "speedup_int8_over_bf16_equal_bytes": speedup,
        "cache_byte_ratio_int8_over_bf16": byte_ratio,
    }
    line = (f"bf16@{slots_bf16}slots={out['bf16']['tokens_per_s']:.1f} "
            f"tok/s int8@{slots_int8}slots="
            f"{out['int8']['tokens_per_s']:.1f} tok/s "
            f"speedup={speedup:.2f}x byte_ratio={byte_ratio:.2f}")
    print(f"  [kv_quant] {line}", flush=True)
    if csv is not None:
        csv.add("kv_quant/equal_bytes_throughput",
                1e6 / max(out["int8"]["tokens_per_s"], 1e-9),
                f"speedup={speedup:.2f};byte_ratio={byte_ratio:.2f}")
    if not tiny:
        # Acceptance (ISSUE 5): ≥ 1.3x tokens/s at ~equal cache bytes.
        assert speedup >= 1.3, serving_section
        assert byte_ratio <= 1.15, serving_section

    out_path = out_path or os.path.join(common.CACHE_DIR,
                                        "BENCH_kv_quant.json")
    common.merge_json_section(out_path, "serving", serving_section)
    print(f"  [kv_quant] wrote {out_path} (serving section)", flush=True)
    return serving_section


# --------------------------------------------------------------------------
# Mesh-sharded scenario (`--mesh`): tensor-parallel continuous batching.
#
# The same mixed-traffic scheduler workload on a (data, model) device mesh:
# params and the live KV state shard per launch/shardings serving rules,
# decode runs the shard_map/GSPMD-partitioned program. On a CPU host the
# mesh is simulated with fake host devices (xla_force_host_platform_
# device_count), which execute the partitioned SPMD program *serially* on
# one core: wall ≈ n_devices x per-device time, so
#     tokens_per_s_simulated = tokens x n_devices / wall
# estimates the per-device-parallel rate (every shard runs the same
# program on 1/n of the heads/slots — SPMD symmetry). Collectives are host
# memcpys, optimistic vs real ICI; the raw serialized rate is reported
# alongside. Emits ``experiments/BENCH_sharded_serving.json``.
# --------------------------------------------------------------------------

_MESH_METHODOLOGY = (
    "Fake host devices execute the GSPMD-partitioned program serially on "
    "one CPU core, so wall ~= n_devices * per-device time; "
    "tokens_per_s_simulated = tokens * n_devices / wall_s estimates the "
    "per-device-parallel rate (SPMD symmetry: each device runs the same "
    "program over 1/n of the kv-heads / slots). Collectives are host "
    "memcpys (optimistic vs real interconnect); tokens_per_s_wall is the "
    "raw serialized rate.")


def benchmark_mesh(*, tiny: bool = False, out_path: str | None = None,
                   csv: common.CsvOut | None = None,
                   mesh_arg: str | None = None) -> dict:
    from repro.serving.meshing import ServingMesh, parse_mesh_arg

    if tiny:
        n_req, prompt_len, max_new_grid, segment_len = 4, 12, (4, 8), 4
        capacity, slots, repeats = 32, 4, 1
        cfg = dataclasses.replace(common.bench_arch(512),
                                  n_heads=8, n_kv_heads=4)
    else:
        # per-device compute must dominate the host-side scheduler tax for
        # the serialized-wall normalisation to be clean -> the larger
        # serving model; n_kv_heads=4 so every swept tp divides the heads
        n_req, prompt_len, max_new_grid, segment_len = 16, 32, (8, 32), 8
        capacity, slots, repeats = 64, 4, 3
        cfg = dataclasses.replace(common.bench_arch(512), n_layers=6,
                                  d_model=256, n_heads=8, n_kv_heads=4,
                                  d_head=32, d_ff=512)

    shapes = ([tuple(parse_mesh_arg(mesh_arg))]
              if mesh_arg and mesh_arg != "sweep"
              else [(1, 2), (1, 4), (2, 4)])

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = common.make_policy_for("lethe", capacity)
    reqs = _make_requests(n_req, prompt_len, max_new_grid, cfg.vocab_size)

    def measure(eng: Engine) -> tuple[dict, dict]:
        _run_once("continuous", eng, list(reqs), slots, segment_len)  # warm
        wall, toks = float("inf"), {}
        for _ in range(repeats):
            w, done, _ = _run_once("continuous", eng, list(reqs), slots,
                                   segment_len)
            wall = min(wall, w)
            toks = {c.uid: np.asarray(c.tokens) for c in done}
        tokens = int(sum(len(t) for t in toks.values()))
        return {"wall_s": wall, "tokens": tokens,
                "tokens_per_s_wall": tokens / max(wall, 1e-9)}, toks

    single, toks0 = measure(Engine(model, params, pol))
    single["tokens_per_s_simulated"] = single["tokens_per_s_wall"]
    curve = []
    for dp, tp in shapes:
        mesh = ServingMesh.build((dp, tp))
        r, toks = measure(Engine(model, params, pol, mesh=mesh))
        # differential guard: the mesh run must produce the exact tokens
        for uid, t in toks0.items():
            np.testing.assert_array_equal(toks[uid], t,
                                          err_msg=f"mesh {dp}x{tp} uid {uid}")
        n_dev = dp * tp
        r["tokens_per_s_simulated"] = r["tokens"] * n_dev / max(
            r["wall_s"], 1e-9)
        r["mesh"] = f"{dp}x{tp}"
        r["n_devices"] = n_dev
        r["device_topology"] = common.device_topology(mesh)
        r["speedup_simulated_vs_single"] = (
            r["tokens_per_s_simulated"]
            / max(single["tokens_per_s_simulated"], 1e-9))
        curve.append(r)
        line = (f"mesh={dp}x{tp} wall={r['wall_s']:.2f}s "
                f"tok/s_wall={r['tokens_per_s_wall']:.1f} "
                f"tok/s_sim={r['tokens_per_s_simulated']:.1f} "
                f"({r['speedup_simulated_vs_single']:.2f}x vs single)")
        print(f"  [sharded_serving] {line}", flush=True)
        if csv is not None:
            csv.add(f"sharded_serving/mesh{dp}x{tp}",
                    1e6 / max(r["tokens_per_s_simulated"], 1e-9),
                    f"speedup_sim={r['speedup_simulated_vs_single']:.2f}")

    results = {"config": {
        "n_requests": n_req, "prompt_len": prompt_len,
        "max_new_grid": list(max_new_grid), "segment_len": segment_len,
        "slots": slots, "capacity": capacity, "policy": "lethe",
        "tiny": tiny, "n_layers": cfg.n_layers, "d_model": cfg.d_model,
        "n_kv_heads": cfg.n_kv_heads,
        "device_topology": common.device_topology(),
        "methodology": _MESH_METHODOLOGY,
    }, "single": single, "mesh_runs": curve}

    if not tiny and mesh_arg in (None, "sweep"):
        # Acceptance: simulated tokens/s grows monotonically with the
        # model-axis size and clears 1.3x by 4-way tensor parallel.
        by_tp = {1: single["tokens_per_s_simulated"]}
        for r in curve:
            dp, tp = (int(x) for x in r["mesh"].split("x"))
            if dp == 1:
                by_tp[tp] = r["tokens_per_s_simulated"]
        tps_curve = [by_tp[t] for t in sorted(by_tp)]
        assert all(a < b for a, b in zip(tps_curve, tps_curve[1:])), by_tp
        assert by_tp[4] / by_tp[1] >= 1.3, by_tp
        results["tp_scaling_simulated"] = {str(t): by_tp[t]
                                           for t in sorted(by_tp)}

    out_path = out_path or os.path.join(common.CACHE_DIR,
                                        "BENCH_sharded_serving.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"  [sharded_serving] wrote {out_path}", flush=True)
    return results


def run(csv: common.CsvOut) -> None:
    """benchmarks/run.py suite hook."""
    benchmark(tiny=False, csv=csv)
    benchmark_chunked(tiny=False, csv=csv)
    benchmark_kv_quant(tiny=False, csv=csv)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one small grid point")
    ap.add_argument("--chunked", action="store_true",
                    help="run the chunked-prefill admission-wave scenario "
                         "instead of the lockstep/continuous comparison")
    ap.add_argument("--kv-format", default=None, choices=["int8"],
                    help="run the bytes-neutral quantized-cache scenario "
                         "(int8 at 2x slots vs bf16 at equal cache bytes)")
    ap.add_argument("--mesh", nargs="?", const="sweep", default=None,
                    metavar="DP,TP",
                    help="run the mesh-sharded serving scenario: bare "
                         "--mesh sweeps (1,2) (1,4) (2,4) against the "
                         "single-device baseline; --mesh 2,4 runs that one "
                         "shape (fake host devices are set up automatically)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.mesh is not None:
        benchmark_mesh(tiny=args.tiny, out_path=args.out,
                       mesh_arg=args.mesh)
        return
    if args.kv_format == "int8":
        benchmark_kv_quant(tiny=args.tiny, out_path=args.out)
        return
    if args.chunked:
        benchmark_chunked(tiny=args.tiny, out_path=args.out)
        return
    res = benchmark(tiny=args.tiny, out_path=args.out)
    if not args.tiny:
        worst = min(r["speedup"] for r in res["runs"].values())
        best = max(r["speedup"] for r in res["runs"].values())
        print(f"speedup over lockstep: min {worst:.2f}x / max {best:.2f}x")


if __name__ == "__main__":
    main()
